"""Declarative experiment specs: one JSON document describes a whole run.

The paper's claims are *comparative* (diffusion vs. GPFS baselines across
dispatch policies, provisioning policies and demand curves), so an
experiment must be a value, not a construction recipe: the same spec has to
run unmodified on the discrete-event simulator (`DiffusionSim`) and the
threaded runtime (`DiffusionRuntime`) and yield reports with one schema.

:class:`ExperimentSpec` is a frozen dataclass tree --

  cluster       testbed binding (by name), pool size, CPUs per node
  cache         capacity / eviction policy / enabled
  policy        dispatch policy (the paper's four, by value string)
  provisioner   DRP knobs, or None for a fixed pool
  workload      EITHER a generator binding (arrival-process + popularity
                specs, the same ``{"kind": ClassName, ...}`` dicts the
                trace header records) OR a JSONL ``trace_path``
  seed          engine seed (cache RNGs, peer choice)

-- with strict JSON round-tripping: ``from_dict(to_dict(s)) == s`` bit-for-
bit, and unknown fields hard-error at every nesting level (a half-applied
spec silently skews every number downstream of it; see trace.py for the
same stance on trace versions).

Alias map.  Historically the two engines grew divergent constructor
surfaces (``SimConfig`` fields vs. ``DiffusionRuntime`` kwargs).  The spec
layer is now the single source of knob names *and defaults*: ``ALIASES``
documents, for every spec field, the engine-side parameter it binds to, and
``DOCUMENTED_DIVERGENCES`` records the places the raw engine defaults
disagree (the spec always passes explicit values, so the divergence can
never leak into a run).  :func:`check_alias_map` verifies both tables
against the live constructor signatures and hard-errors on drift --
renaming an engine knob without updating the spec layer fails loudly
instead of silently falling back to an engine default.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import IO, Mapping, Optional, Union

from repro.core.cache import EvictionPolicy
from repro.core.policies import DispatchPolicy
from repro.core.provisioner import AllocationPolicy
from repro.core.testbeds import TESTBEDS
from repro.workloads import ARRIVALS, DAGS, POPULARITY, SESSIONS


# --------------------------------------------------------------------------
# spec tree
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ClusterSpec:
    """Pool shape + testbed binding (by registry name, so specs stay JSON)."""

    testbed: str = "anl_uc"
    n_nodes: int = 16          # initial pool (the provisioner grows from here)
    cpus_per_node: int = 1     # simulator only; runtime workers are 1-slot

    def __post_init__(self) -> None:
        if self.testbed not in TESTBEDS:
            raise ValueError(f"unknown testbed {self.testbed!r} "
                             f"(known: {sorted(TESTBEDS)})")
        if self.n_nodes < 0:
            raise ValueError("n_nodes must be >= 0")
        if self.cpus_per_node < 1:
            raise ValueError("cpus_per_node must be >= 1")


@dataclass(frozen=True)
class CacheSpec:
    """Per-executor cache shape.  ``enabled=False`` is the paper's
    data-unaware baseline (every byte from the persistent store)."""

    capacity_bytes: int = 50 * 10**9    # the spec-level default (see ALIASES)
    eviction: str = "lru"
    enabled: bool = True

    def __post_init__(self) -> None:
        if self.capacity_bytes < 0:
            raise ValueError("capacity_bytes must be >= 0")
        EvictionPolicy(self.eviction)   # raises on unknown value


@dataclass(frozen=True)
class ProvisionerSpec:
    """DynamicResourceProvisioner knobs (Falkon §3.1), field-for-field."""

    policy: str = "all-at-once"
    min_executors: int = 0
    max_executors: int = 64
    additive_k: int = 8
    queue_threshold: int = 1
    idle_timeout_s: float = 60.0
    trigger_cooldown_s: float = 1.0
    period_s: float = 1.0               # provisioner tick interval

    def __post_init__(self) -> None:
        AllocationPolicy(self.policy)   # raises on unknown value
        if not 0 <= self.min_executors <= self.max_executors:
            raise ValueError("need 0 <= min_executors <= max_executors")
        if self.period_s <= 0 or self.trigger_cooldown_s < 0:
            raise ValueError("period_s > 0 and trigger_cooldown_s >= 0")


@dataclass(frozen=True)
class WorkloadSpec:
    """Workload binding: a generator recipe, a DAG recipe, a session
    recipe, OR a recorded JSONL trace -- exactly one of the four.

    Generator binding uses the same ``{"kind": ClassName, ...ctor kwargs}``
    dicts that :meth:`ArrivalProcess.spec` / :meth:`PopularityModel.spec`
    emit into trace headers, so a trace header's spec block is itself a
    valid binding.  ``object_prefix`` names synthetic catalog objects
    ``{prefix}{i}`` (matching ``repro.core.make_objects``); when None the
    generator's own ``{name}.o{i}`` scheme applies.

    ``dag`` binds a structured-pipeline recipe the same way:
    ``{"kind": "all_pairs" | "reduce_tree" | "stacking_pyramid",
    ...ctor kwargs}`` against the ``repro.workloads.DAGS`` registry (a DAG
    Workload's own ``spec`` dict is itself a valid binding).  The flat
    generator knobs are meaningless for a DAG -- shape comes from the
    binding -- so non-default values hard-error rather than being dropped.

    ``sessions`` binds a multi-turn serving workload the same way:
    ``{"kind": "chat", ...SessionModel kwargs}`` against the
    ``repro.workloads.SESSIONS`` registry (a session Workload's own
    ``spec`` dict is itself a valid binding).  Same dead-knob rule as
    trace/dag bindings.
    """

    name: str = "wl"
    arrivals: Optional[dict] = None
    popularity: Optional[dict] = None
    n_tasks: int = 0
    n_objects: int = 0
    object_bytes: int = 0
    object_prefix: Optional[str] = None
    compute_seconds: float = 0.0
    output_bytes: int = 0
    store_metadata_ops: int = 0
    seed: int = 0
    trace_path: Optional[str] = None
    dag: Optional[dict] = None
    sessions: Optional[dict] = None

    def __post_init__(self) -> None:
        generator = self.arrivals if self.arrivals is not None \
            else self.popularity
        n_bindings = sum(b is not None for b in (self.trace_path, self.dag,
                                                 self.sessions, generator))
        if n_bindings > 1:
            raise ValueError("workload binds EXACTLY ONE of trace_path, dag, "
                             "sessions, or a generator "
                             "(arrivals+popularity)")
        if (self.trace_path is not None or self.dag is not None
                or self.sessions is not None):
            # flat-generator knobs have no effect on a replayed trace, a
            # DAG recipe, or a session recipe; accepting them would
            # silently drop user intent (e.g. a seed "sweep" that replays
            # the identical trace, or an n_tasks that a DAG's own shape
            # parameters ignore)
            dead = [f.name for f in dataclasses.fields(self)
                    if f.name not in ("name", "trace_path", "dag", "sessions",
                                      "arrivals", "popularity")
                    and getattr(self, f.name) != f.default]
            if dead:
                which = ("trace-bound" if self.trace_path is not None
                         else "dag-bound" if self.dag is not None
                         else "sessions-bound")
                raise ValueError(
                    f"{which} workload: generator field(s) {dead} "
                    f"would be silently ignored (change them in the "
                    f"trace / the dag / the sessions binding instead)")
            if self.dag is not None and self.dag.get("kind") not in DAGS:
                raise ValueError(f"unknown dag kind "
                                 f"{self.dag.get('kind')!r} "
                                 f"(known: {sorted(DAGS)})")
            if self.sessions is not None \
                    and self.sessions.get("kind") not in SESSIONS:
                raise ValueError(f"unknown sessions kind "
                                 f"{self.sessions.get('kind')!r} "
                                 f"(known: {sorted(SESSIONS)})")
            return
        if self.arrivals is None or self.popularity is None:
            raise ValueError("workload needs a trace_path, a dag binding, a "
                             "sessions binding, or a generator binding "
                             "(arrivals AND popularity)")
        for label, d, registry in (("arrivals", self.arrivals, ARRIVALS),
                                   ("popularity", self.popularity, POPULARITY)):
            kind = d.get("kind")
            if kind not in registry:
                raise ValueError(f"unknown {label} kind {kind!r} "
                                 f"(known: {sorted(registry)})")
        if self.n_tasks <= 0:
            raise ValueError("generator workloads need n_tasks > 0")
        if self.n_objects <= 0:
            raise ValueError("generator workloads need n_objects > 0")


@dataclass(frozen=True)
class ObserveSpec:
    """Lifecycle-event recording (repro.obs, DESIGN.md §10).  Off by
    default: the engines' hot paths carry only a None-check.  When on, the
    engine installs a bounded `Recorder` (drop-oldest ring of
    ``ring_capacity`` events) and, if ``sink_path`` is set, dumps the ring
    to JSONL after the run."""

    events: bool = False
    sink_path: Optional[str] = None
    ring_capacity: int = 65536
    # live telemetry plane (DESIGN.md §13): periodic registry sampling,
    # optional JSONL time-series sink, optional status endpoint port
    # (0 = pick a free port when metrics are on; the engine exposes the
    # bound address).  Same free-when-off stance as `events`.
    metrics: bool = False
    metrics_interval_s: float = 0.25
    metrics_sink_path: Optional[str] = None
    metrics_port: int = -1      # -1 = no endpoint; >= 0 = bind (0 = any)

    def __post_init__(self) -> None:
        if self.ring_capacity < 1:
            raise ValueError("ring_capacity must be >= 1")
        if self.sink_path is not None and not self.events:
            raise ValueError("observe.sink_path requires observe.events "
                             "(a sink with recording off would silently "
                             "write an empty trace)")
        if self.metrics_interval_s <= 0:
            raise ValueError("metrics_interval_s must be > 0")
        if self.metrics_sink_path is not None and not self.metrics:
            raise ValueError("observe.metrics_sink_path requires "
                             "observe.metrics (a sink with telemetry off "
                             "would silently write an empty series)")
        if self.metrics_port >= 0 and not self.metrics:
            raise ValueError("observe.metrics_port requires observe.metrics "
                             "(an endpoint with telemetry off would serve "
                             "nothing)")


@dataclass(frozen=True)
class ExperimentSpec:
    """The one declarative object either engine executes (DESIGN.md §7)."""

    name: str
    workload: WorkloadSpec
    cluster: ClusterSpec = field(default_factory=ClusterSpec)
    cache: CacheSpec = field(default_factory=CacheSpec)
    policy: str = "max-compute-util"
    provisioner: Optional[ProvisionerSpec] = None
    seed: int = 0
    # engine-specific knobs (see ALIASES for which engine honours which;
    # the other engine hard-errors on a non-default value instead of
    # silently ignoring it)
    write_outputs_to: str = "local"         # sim: local | store | none
    index_update_interval_s: float = 0.0    # sim: 0 => synchronous
    index_update_batch: int = 1             # runtime: >1 => loose coherence
    release_policy: str = "discard"         # sim: discard | rebalance
    flow_solver: str = "incremental"        # sim: incremental | naive
    speculation_factor: float = 0.0         # sim: straggler twins
    # runtime only, fleet mode (repro.fleet): hosts > 0 runs the executors
    # across `hosts` OS processes of `threads_per_host` executor threads
    # each.  cluster.n_nodes must then equal hosts * threads_per_host --
    # the pool SIZE stays the cluster's business, its process layout is an
    # engine knob.  hosts = 0 is the classic in-process thread pool.
    hosts: int = 0
    threads_per_host: int = 1
    # fleet wire/dispatch shape (PR 6): frames per batch on each host
    # connection, and hierarchical per-host local dispatch (hosts score
    # and claim leased work against a forwarded index replica).  Both are
    # scheduling-neutral under batch-synchronous replay (DESIGN.md §9).
    wire_batch: int = 64
    local_dispatch: bool = False
    # observability (PR 7): lifecycle-event recording, engine-neutral
    observe: ObserveSpec = field(default_factory=ObserveSpec)

    def __post_init__(self) -> None:
        DispatchPolicy(self.policy)         # raises on unknown value
        if self.write_outputs_to not in ("local", "store", "none"):
            raise ValueError("write_outputs_to must be local|store|none")
        if self.release_policy not in ("discard", "rebalance"):
            raise ValueError("release_policy must be discard|rebalance")
        if self.flow_solver not in ("incremental", "naive"):
            raise ValueError("flow_solver must be incremental|naive")
        if self.index_update_batch < 1:
            raise ValueError("index_update_batch must be >= 1")
        if self.hosts < 0:
            raise ValueError("hosts must be >= 0 (0 = in-process threads)")
        if self.threads_per_host < 1:
            raise ValueError("threads_per_host must be >= 1")
        if self.hosts == 0 and self.threads_per_host != 1:
            raise ValueError("threads_per_host only applies to fleet runs; "
                             "set hosts > 0 (or leave threads_per_host at 1)")
        if self.wire_batch < 1:
            raise ValueError("wire_batch must be >= 1")
        if self.hosts == 0 and self.wire_batch != 64:
            raise ValueError("wire_batch only applies to fleet runs; "
                             "set hosts > 0 (or leave wire_batch at 64)")
        if self.hosts == 0 and self.local_dispatch:
            raise ValueError("local_dispatch only applies to fleet runs; "
                             "set hosts > 0")
        if self.hosts > 0 and self.cluster.n_nodes != \
                self.hosts * self.threads_per_host:
            raise ValueError(
                f"fleet layout mismatch: cluster.n_nodes="
                f"{self.cluster.n_nodes} but hosts*threads_per_host="
                f"{self.hosts * self.threads_per_host} (the pool size and "
                f"its process layout must agree)")

    # -- serialisation ------------------------------------------------------
    def to_dict(self) -> dict:
        """Plain JSON-able dict (recursive; ``provisioner`` may be None)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "ExperimentSpec":
        """Strict inverse of :meth:`to_dict`: unknown fields hard-error."""
        return _from_dict(cls, d, path="spec")

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))

    def save(self, path: Union[str, Path]) -> None:
        Path(path).write_text(self.to_json() + "\n")

    @classmethod
    def load(cls, path: Union[str, Path, IO[str]]) -> "ExperimentSpec":
        if hasattr(path, "read"):
            return cls.from_json(path.read())
        return cls.from_json(Path(path).read_text())

    def fingerprint(self) -> str:
        """Stable short content hash (ties a RunReport to its spec)."""
        canon = json.dumps(self.to_dict(), sort_keys=True)
        return hashlib.sha256(canon.encode()).hexdigest()[:16]


#: nested dataclass types, by (owner, field name)
_SUBSPECS: dict[tuple[type, str], type] = {
    (ExperimentSpec, "workload"): WorkloadSpec,
    (ExperimentSpec, "cluster"): ClusterSpec,
    (ExperimentSpec, "cache"): CacheSpec,
    (ExperimentSpec, "provisioner"): ProvisionerSpec,
    (ExperimentSpec, "observe"): ObserveSpec,
}


def _from_dict(cls: type, d: Mapping, path: str):
    if not isinstance(d, Mapping):
        raise ValueError(f"{path}: expected a mapping, got {type(d).__name__}")
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - names)
    if unknown:
        raise ValueError(f"{path}: unknown field(s) {unknown} "
                         f"(known: {sorted(names)})")
    required = {f.name for f in dataclasses.fields(cls)
                if f.default is dataclasses.MISSING
                and f.default_factory is dataclasses.MISSING}  # type: ignore
    missing = sorted(required - set(d))
    if missing:
        raise ValueError(f"{path}: missing required field(s) {missing}")
    kw = {}
    for f in dataclasses.fields(cls):
        if f.name not in d:
            continue
        v = d[f.name]
        sub = _SUBSPECS.get((cls, f.name))
        if sub is not None and v is not None:
            v = _from_dict(sub, v, f"{path}.{f.name}")
        kw[f.name] = v
    return cls(**kw)


# --------------------------------------------------------------------------
# dotted-path overrides (the sweep runner's cell expansion)
# --------------------------------------------------------------------------

def with_overrides(spec: ExperimentSpec,
                   overrides: Mapping[str, object]) -> ExperimentSpec:
    """A copy of ``spec`` with dotted-path fields replaced, e.g.
    ``{"provisioner.policy": "exponential", "cache.capacity_bytes": 0}``.
    Paths traverse dataclass fields and dict keys (``workload.arrivals``
    replaces the whole arrival binding).  Validation re-runs on every
    replaced node, so an override that breaks an invariant hard-errors."""
    for p, v in overrides.items():
        segs = p.split(".")
        if not all(segs):
            raise ValueError(f"bad override path {p!r}")
        spec = _set_path(spec, p, segs, v)
    return spec


def _set_path(node, full_path: str, segs: list[str], value):
    head, rest = segs[0], segs[1:]
    if dataclasses.is_dataclass(node) and not isinstance(node, type):
        if head not in {f.name for f in dataclasses.fields(node)}:
            raise ValueError(f"override path {full_path!r}: "
                             f"{type(node).__name__} has no field {head!r}")
        cur = getattr(node, head)
        sub = _SUBSPECS.get((type(node), head))
        if rest:
            if cur is None:
                raise ValueError(f"override path {full_path!r}: "
                                 f"{head!r} is None in the base spec")
            value = _set_path(cur, full_path, rest, value)
        elif sub is not None and isinstance(value, Mapping):
            # a dict assigned to a sub-spec field parses strictly (a raw
            # dict would skip validation and crash deep in an engine)
            value = _from_dict(sub, value, full_path)
        return dataclasses.replace(node, **{head: value})
    if isinstance(node, dict):
        if head not in node:
            # inserting a new key would silently typo-tolerate (the layer's
            # strictness stance); replace the whole dict to change its keys
            raise ValueError(f"override path {full_path!r}: "
                             f"dict has no key {head!r} "
                             f"(existing: {sorted(node)})")
        out = dict(node)
        out[head] = _set_path(node[head], full_path, rest, value) if rest \
            else value
        return out
    raise ValueError(f"override path {full_path!r}: cannot descend into "
                     f"{type(node).__name__}")


# --------------------------------------------------------------------------
# engine knob alias map (the documented SimConfig <-> DiffusionRuntime
# correspondence; drift-checked against the live signatures)
# --------------------------------------------------------------------------

#: spec path -> (SimConfig field, DiffusionRuntime.__init__ kwarg).  None on
#: one side = that engine has no such knob; a spec setting a non-default
#: value for it must hard-error on that engine (enforced by the engine
#: adapters), never be silently dropped.
ALIASES: dict[str, tuple[Optional[str], Optional[str]]] = {
    "cluster.n_nodes":         ("n_nodes", "n_executors"),
    "cluster.cpus_per_node":   ("cpus_per_node", None),
    "cache.capacity_bytes":    ("cache_capacity_bytes", "cache_capacity_bytes"),
    "cache.eviction":          ("cache_policy", "cache_policy"),
    "cache.enabled":           ("caching_enabled", None),
    "policy":                  ("policy", "policy"),
    "seed":                    ("seed", "seed"),
    "provisioner":             ("provisioner", None),
    "provisioner.period_s":    ("provisioner_period_s", None),
    "write_outputs_to":        ("write_outputs_to", None),
    "index_update_interval_s": ("index_update_interval_s", None),
    "index_update_batch":      (None, "index_update_batch"),
    "release_policy":          ("release_policy", None),
    "flow_solver":             ("flow_solver", None),
    "speculation_factor":      ("speculation_factor", None),
    # fleet mode: the runtime-side names resolve against FleetRuntime (the
    # spec paths in FLEET_PATHS), not DiffusionRuntime -- hosts=0 never
    # reaches a FleetRuntime, and hosts>0 hard-errors on the simulator.
    "hosts":                   (None, "hosts"),
    "threads_per_host":        (None, "threads_per_host"),
    "wire_batch":              (None, "wire_batch"),
    "local_dispatch":          (None, "local_dispatch"),
}

#: spec paths whose runtime-side alias is a FleetRuntime ctor kwarg
FLEET_PATHS = frozenset({"hosts", "threads_per_host", "wire_batch",
                         "local_dispatch"})

#: FleetRuntime ctor kwargs that deliberately have no spec field: the task
#: callable registry name and transport/liveness/deployment tuning are
#: operational knobs of a concrete deployment, not part of the
#: experiment's identity (lease_depth shapes host-side queue depth, not
#: placement under replay; bind_host is the multi-machine seam).
FLEET_OPERATIONAL_KWARGS = frozenset({
    "task_fn_name", "codec", "heartbeat_interval_s", "heartbeat_timeout_s",
    "spawn_timeout_s", "lease_depth", "bind_host"})

#: raw engine-side default disagreements the spec layer papers over by
#: always passing explicit values.  check_alias_map() verifies these are
#: exactly the divergences that exist: an engine default changing (or the
#: divergence disappearing) hard-errors until this table is updated.
DOCUMENTED_DIVERGENCES: dict[str, dict[str, object]] = {
    # sim was sized for the paper's 50 GB node caches; the in-process
    # runtime defaulted to 1 GiB so unit tests fit in RAM.
    "cache.capacity_bytes": {"sim": 50 * 10**9, "runtime": 1 << 30},
}

_MISSING = object()


def _sim_defaults() -> dict[str, object]:
    out = {}
    from repro.core.simulator import SimConfig
    for f in dataclasses.fields(SimConfig):
        if f.default is not dataclasses.MISSING:
            out[f.name] = f.default
        elif f.default_factory is not dataclasses.MISSING:  # type: ignore
            out[f.name] = _MISSING   # factory defaults: treat as no-literal
        else:
            out[f.name] = _MISSING
    return out


def _ctor_defaults(cls) -> dict[str, object]:
    import inspect

    sig = inspect.signature(cls.__init__)
    return {n: (p.default if p.default is not inspect.Parameter.empty
                else _MISSING)
            for n, p in sig.parameters.items() if n != "self"}


def _runtime_defaults() -> dict[str, object]:
    from repro.core.runtime import DiffusionRuntime
    return _ctor_defaults(DiffusionRuntime)


def _fleet_defaults() -> dict[str, object]:
    from repro.fleet.runtime import FleetRuntime
    return _ctor_defaults(FleetRuntime)


_alias_map_checked = False


def check_alias_map() -> None:
    """Verify ALIASES + DOCUMENTED_DIVERGENCES against the live engine
    signatures; raise RuntimeError on any drift.  Cheap, cached."""
    global _alias_map_checked
    if _alias_map_checked:
        return
    sim, rt = _sim_defaults(), _runtime_defaults()
    fleet = _fleet_defaults()
    problems: list[str] = []
    for path, (sim_name, rt_name) in ALIASES.items():
        if sim_name is not None and sim_name not in sim:
            problems.append(f"{path}: SimConfig has no field {sim_name!r}")
        if path in FLEET_PATHS:
            if rt_name is not None and rt_name not in fleet:
                problems.append(f"{path}: FleetRuntime has no kwarg "
                                f"{rt_name!r}")
            continue
        if rt_name is not None and rt_name not in rt:
            problems.append(f"{path}: DiffusionRuntime has no kwarg "
                            f"{rt_name!r}")
        if sim_name is None or rt_name is None:
            continue
        s_def, r_def = sim.get(sim_name, _MISSING), rt.get(rt_name, _MISSING)
        if s_def is _MISSING or r_def is _MISSING:
            continue   # required on one side: the spec always passes it
        diverges = s_def != r_def
        documented = path in DOCUMENTED_DIVERGENCES
        if diverges and not documented:
            problems.append(
                f"{path}: engine defaults silently differ "
                f"(sim {sim_name}={s_def!r} vs runtime {rt_name}={r_def!r}); "
                f"document it in DOCUMENTED_DIVERGENCES")
        elif diverges and documented:
            doc = DOCUMENTED_DIVERGENCES[path]
            if doc.get("sim") != s_def or doc.get("runtime") != r_def:
                problems.append(f"{path}: DOCUMENTED_DIVERGENCES is stale "
                                f"({doc} vs sim={s_def!r} runtime={r_def!r})")
        elif not diverges and documented:
            problems.append(f"{path}: documented divergence no longer "
                            f"exists; remove it from DOCUMENTED_DIVERGENCES")
    sim_covered = {s for s, _ in ALIASES.values() if s is not None}
    # testbed/executor_slowdown/fail_at are sim-only experiment machinery;
    # recorder and metrics are the obs layer's injection points on BOTH
    # engines, built by the engine adapters from spec.observe (not knobs a
    # spec aliases).
    missing = set(sim) - sim_covered - {"testbed", "executor_slowdown",
                                        "fail_at", "recorder", "metrics"}
    if missing:
        problems.append(f"SimConfig fields not covered by ALIASES: "
                        f"{sorted(missing)}")
    rt_covered = {r for _, r in ALIASES.values() if r is not None}
    missing_rt = set(rt) - rt_covered - {"store", "recorder", "metrics"}
    if missing_rt:
        problems.append(f"DiffusionRuntime kwargs not covered by ALIASES: "
                        f"{sorted(missing_rt)}")
    # fleet drift: FleetRuntime must accept every DiffusionRuntime knob
    # (except the executor count it derives from hosts*threads_per_host)
    # with an IDENTICAL default -- a new runtime knob that never reaches
    # the fleet ctor, or a silently different fleet default, fails here.
    for name, r_def in rt.items():
        if name in ("n_executors", "store"):
            continue
        if name not in fleet:
            problems.append(f"FleetRuntime is missing DiffusionRuntime "
                            f"kwarg {name!r}")
        elif fleet[name] != r_def:
            problems.append(f"FleetRuntime default for {name!r} "
                            f"({fleet[name]!r}) silently diverges from "
                            f"DiffusionRuntime's ({r_def!r})")
    fleet_covered = ({r for p, (_, r) in ALIASES.items()
                      if p in FLEET_PATHS and r is not None}
                     | (set(rt) - {"n_executors"}))
    missing_fleet = set(fleet) - fleet_covered - FLEET_OPERATIONAL_KWARGS
    if missing_fleet:
        problems.append(f"FleetRuntime kwargs not covered by ALIASES or "
                        f"FLEET_OPERATIONAL_KWARGS: {sorted(missing_fleet)}")
    if problems:
        raise RuntimeError(
            "experiment spec layer out of sync with engine signatures:\n  "
            + "\n  ".join(problems))
    _alias_map_checked = True

"""RunReport: one comparable result schema for both engines.

`DiffusionSim` historically reported through `SimResult` (+ `RunMetrics`
via the workload layer's MetricsCollector) while `DiffusionRuntime`
reported through `RuntimeLedger` ad hoc.  A :class:`RunReport` unifies them
field-for-field: every metric is computed by the SAME code path
(``repro.workloads.MetricsCollector``) from a `SimResult`-shaped view of
the engine's observables, so "cache_hit_ratio" or "avg_slowdown" mean
*exactly* the same formula on both engines and a sim run and a runtime run
of one spec diff field-by-field (:meth:`RunReport.diff`).

Clock semantics are the one intentional difference: simulator reports are
in simulated seconds, runtime reports in wall seconds -- the ``engine``
field tags which.  Everything else (hit ratios, join splits, byte ledgers,
slowdown, performance index, pool/membership history) shares definitions;
DESIGN.md §7 is the field glossary.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Mapping

#: provenance / environment fields excluded from cross-engine diffs by
#: default (they legitimately differ between a sim and a runtime run)
IDENTITY_FIELDS = ("experiment", "engine", "spec_sha", "seed", "wall_s")


@dataclass(frozen=True)
class RunReport:
    # -- provenance ---------------------------------------------------------
    experiment: str                 # spec name
    engine: str                     # "sim" | "runtime"
    spec_sha: str                   # ExperimentSpec.fingerprint()
    seed: int
    wall_s: float                   # host wall clock spent executing
    # -- counts -------------------------------------------------------------
    n_tasks: int
    n_completed: int
    n_failed: int
    # -- clocks (engine time: simulated s | wall s) -------------------------
    makespan_s: float
    t_first_dispatch: float
    t_last_complete: float
    busy_span_s: float
    tasks_per_second: float
    # -- cache economics (per-input accounting, identical on both engines) --
    local_hits: int
    peer_hits: int
    store_reads: int
    local_hit_ratio: float
    cache_hit_ratio: float          # (local + peer) / all accesses
    # -- join (multi-input) split over completed tasks ----------------------
    mean_inputs_per_task: float
    full_hit_tasks: int
    partial_hit_tasks: int
    zero_hit_tasks: int
    # -- bytes / bandwidth --------------------------------------------------
    bytes_by_kind: dict             # kind -> bytes (local/c2c/store_read/...)
    read_bandwidth_bps: float
    moved_bandwidth_bps: float
    efficiency: float               # read bw / testbed ideal at peak pool
    # -- 0808.3535 workload metrics -----------------------------------------
    avg_slowdown: float
    p95_slowdown: float
    performance_index: float
    # -- elasticity / membership -------------------------------------------
    peak_executors: int
    low_executors: int
    executor_seconds: float
    n_allocated: int                # 0 on fixed-pool runs
    n_released: int
    pool_log: tuple                 # ((t, live executors), ...) samples
    # -- dispatcher internals (runtime only; {} on sim runs) ----------------
    dispatch_stats: dict            # DispatchStats.as_dict(): pump counts,
                                    # lock hold time, wire frame/msg totals
    # -- sim<->real divergence (repro.obs.diff output; {} unless a diff
    # joined this run's measured outcomes against a sim-twin replay) --------
    task_divergence: dict = dataclasses.field(default_factory=dict)
    # -- live-telemetry final snapshot (DESIGN.md §13; {} unless
    # observe.metrics ran): the run's last registry snapshot, per-host
    # snapshots + cluster fold, sample/health-event counts, and the
    # recorder drop count (present whenever a recorder ran) -----------------
    telemetry: dict = dataclasses.field(default_factory=dict)
    # -- DAG slowdown bases (defaulted: pre-PR-8 result files stay readable).
    # arrival = avg_slowdown's basis (submit -> end); ready measures from the
    # moment deps were met, so dep-wait does not read as scheduler queueing.
    # Dep-free runs: all three are equal.
    slowdown_from_arrival: float = 0.0
    slowdown_from_ready: float = 0.0

    # ------------------------------------------------------------------
    @classmethod
    def schema(cls) -> tuple[str, ...]:
        """Ordered field names -- identical for every engine by design."""
        return tuple(f.name for f in dataclasses.fields(cls))

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["pool_log"] = [list(p) for p in self.pool_log]
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "RunReport":
        """Strict inverse of :meth:`as_dict` (unknown fields hard-error),
        for reading sweep results JSONL back."""
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - names)
        if unknown:
            raise ValueError(f"RunReport: unknown field(s) {unknown}")
        # defaulted fields (task_divergence) may be absent: pre-PR-7 result
        # files stay readable; fields WITHOUT defaults stay hard-required
        required = {f.name for f in dataclasses.fields(cls)
                    if f.default is dataclasses.MISSING
                    and f.default_factory is dataclasses.MISSING}  # type: ignore
        missing = sorted(required - set(d))
        if missing:
            raise ValueError(f"RunReport: missing field(s) {missing}")
        kw = dict(d)
        kw["pool_log"] = tuple(tuple(p) for p in d["pool_log"])
        kw["bytes_by_kind"] = dict(d["bytes_by_kind"])
        kw["dispatch_stats"] = dict(d["dispatch_stats"])
        if "task_divergence" in kw:
            kw["task_divergence"] = dict(d["task_divergence"])
        if "telemetry" in kw:
            kw["telemetry"] = dict(d["telemetry"])
        return cls(**kw)

    def diff(self, other: "RunReport",
             ignore: tuple[str, ...] = IDENTITY_FIELDS
             + ("pool_log", "dispatch_stats", "task_divergence",
                "telemetry"),
             ) -> dict[str, tuple]:
        """Field-by-field comparison: {field: (self value, other value)}
        for every differing field not in ``ignore``.  Empty dict == the two
        runs agree on every compared number (the sim-vs-runtime diffing the
        trace-v3 roadmap item needs)."""
        out: dict[str, tuple] = {}
        for f in dataclasses.fields(self):
            if f.name in ignore:
                continue
            a, b = getattr(self, f.name), getattr(other, f.name)
            if a != b:
                out[f.name] = (a, b)
        return out


def build_report(spec, engine: str, result, metrics, *, wall_s: float,
                 n_allocated: int = 0, n_released: int = 0,
                 dispatch_stats: Mapping | None = None,
                 task_divergence: Mapping | None = None,
                 telemetry: Mapping | None = None) -> RunReport:
    """Assemble a RunReport from a `SimResult`(-shaped) ``result`` and the
    `RunMetrics` computed from it.  Both engine adapters funnel through
    here, which is what pins the schemas together."""
    return RunReport(
        experiment=spec.name,
        engine=engine,
        spec_sha=spec.fingerprint(),
        seed=spec.seed,
        wall_s=wall_s,
        n_tasks=metrics.n_tasks,
        n_completed=metrics.n_completed,
        n_failed=metrics.n_failed,
        makespan_s=metrics.makespan_s,
        t_first_dispatch=result.t_first_dispatch,
        t_last_complete=result.t_last_complete,
        busy_span_s=metrics.busy_span_s,
        tasks_per_second=metrics.tasks_per_second,
        local_hits=metrics.local_hits,
        peer_hits=metrics.peer_hits,
        store_reads=metrics.store_reads,
        local_hit_ratio=metrics.local_hit_ratio,
        cache_hit_ratio=metrics.cache_hit_ratio,
        mean_inputs_per_task=metrics.mean_inputs_per_task,
        full_hit_tasks=metrics.full_hit_tasks,
        partial_hit_tasks=metrics.partial_hit_tasks,
        zero_hit_tasks=metrics.zero_hit_tasks,
        bytes_by_kind=dict(result.bytes_by_kind),
        read_bandwidth_bps=metrics.read_bandwidth_bps,
        moved_bandwidth_bps=metrics.moved_bandwidth_bps,
        efficiency=metrics.efficiency,
        avg_slowdown=metrics.avg_slowdown,
        p95_slowdown=metrics.p95_slowdown,
        performance_index=metrics.performance_index,
        slowdown_from_arrival=metrics.slowdown_from_arrival,
        slowdown_from_ready=metrics.slowdown_from_ready,
        peak_executors=metrics.peak_executors,
        low_executors=metrics.low_executors,
        executor_seconds=metrics.executor_seconds,
        n_allocated=n_allocated,
        n_released=n_released,
        pool_log=tuple(tuple(p) for p in result.pool_log),
        dispatch_stats=dict(dispatch_stats or {}),
        task_divergence=dict(task_divergence or {}),
        telemetry=dict(telemetry or {}),
    )

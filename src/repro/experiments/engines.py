"""Engine adapters: one `ExperimentSpec` -> either engine -> one `RunReport`.

  SimEngine      discrete-event `DiffusionSim` (simulated clock)
  RuntimeEngine  threaded `DiffusionRuntime` (wall clock, real payloads);
                 with ``spec.hosts > 0`` it drives `repro.fleet.
                 FleetRuntime` instead -- same executors-and-dispatcher
                 model, spread over OS processes

Both follow the same protocol -- ``prepare(spec)`` builds the engine and
binds the workload, ``run()`` executes and returns a :class:`RunReport` --
and both funnel their observables through ``repro.workloads.
MetricsCollector`` via a `SimResult`-shaped view, so every reported number
is computed by one formula regardless of engine (report.py).

Construction is *spec-driven but bit-identical to the legacy paths*: a
`SimEngine` builds exactly the `SimConfig` (and
`DynamicResourceProvisioner`) a hand-written script would, and a
`RuntimeEngine` passes exactly the historical `DiffusionRuntime` kwargs --
regression-locked by tests/test_experiments.py, so existing entry points
and committed baselines stay valid.

Engine-specific knobs hard-error on the other engine (never silently
ignored): a spec with ``flow_solver="naive"`` refuses to run on the
runtime, and ``index_update_batch=4`` refuses to run on the simulator; the
mapping table is ``spec.ALIASES``.  One deliberate translation:
``cache.enabled=False`` (the paper's data-unaware baseline) maps to
zero-capacity caches on the runtime, which has no ``caching_enabled`` knob
-- nothing is ever admitted, so hit/byte accounting matches the
simulator's definition of "no caches".
"""
from __future__ import annotations

import dataclasses
import sys
import threading
import time
from typing import Any, Callable, Optional, Protocol, runtime_checkable

from repro.core.cache import EvictionPolicy
from repro.core.objects import DataObject
from repro.core.policies import DispatchPolicy
from repro.core.provisioner import DynamicResourceProvisioner, AllocationPolicy
from repro.core.runtime import DiffusionRuntime, SHAPE_ONLY_PAYLOAD
from repro.core.simulator import DiffusionSim, SimConfig, SimResult
from repro.core.testbeds import TESTBEDS
from repro.obs import Recorder, outcome_record
from repro.workloads import (ARRIVALS, POPULARITY, MetricsCollector, Workload,
                             build_dag, build_sessions, generate, replay)

from .report import RunReport, build_report
from .spec import ExperimentSpec, ProvisionerSpec, WorkloadSpec, check_alias_map


# --------------------------------------------------------------------------
# spec -> engine ingredients
# --------------------------------------------------------------------------

def build_workload(wspec: WorkloadSpec) -> Workload:
    """Materialise the workload a spec binds: replay its trace, or run the
    generator recipe (bit-identical to calling ``workloads.generate`` with
    the same arguments -- the binding dicts ARE constructor kwargs)."""
    if wspec.trace_path is not None:
        return replay(wspec.trace_path)
    if wspec.dag is not None:
        return build_dag(wspec.dag, name=wspec.name)
    if wspec.sessions is not None:
        return build_sessions(wspec.sessions, name=wspec.name)
    arr = ARRIVALS[wspec.arrivals["kind"]](
        **{k: v for k, v in wspec.arrivals.items() if k != "kind"})
    pop = POPULARITY[wspec.popularity["kind"]](
        **{k: v for k, v in wspec.popularity.items() if k != "kind"})
    objects = None
    if wspec.object_prefix is not None:
        objects = [DataObject(f"{wspec.object_prefix}{i}", wspec.object_bytes)
                   for i in range(wspec.n_objects)]
    return generate(
        wspec.name, arr, pop, n_tasks=wspec.n_tasks,
        objects=objects, n_objects=wspec.n_objects,
        object_bytes=wspec.object_bytes,
        compute_seconds=wspec.compute_seconds,
        output_bytes=wspec.output_bytes,
        store_metadata_ops=wspec.store_metadata_ops,
        seed=wspec.seed)


def build_provisioner(pspec: ProvisionerSpec,
                      allocate_quantum: int = 1) -> DynamicResourceProvisioner:
    """``allocate_quantum`` stays an engine-placement detail (fleet runs
    pass threads_per_host so DRP grow/shrink moves whole hosts)."""
    return DynamicResourceProvisioner(
        min_executors=pspec.min_executors,
        max_executors=pspec.max_executors,
        policy=AllocationPolicy(pspec.policy),
        additive_k=pspec.additive_k,
        queue_threshold=pspec.queue_threshold,
        idle_timeout_s=pspec.idle_timeout_s,
        trigger_cooldown_s=pspec.trigger_cooldown_s,
        allocate_quantum=allocate_quantum)


def build_sim_config(spec: ExperimentSpec,
                     provisioner: Optional[DynamicResourceProvisioner] = None,
                     ) -> SimConfig:
    """The exact `SimConfig` the legacy hand-written path would build --
    every aliased knob passed explicitly (spec defaults win; see
    spec.DOCUMENTED_DIVERGENCES)."""
    return SimConfig(
        testbed=TESTBEDS[spec.cluster.testbed],
        n_nodes=spec.cluster.n_nodes,
        policy=DispatchPolicy(spec.policy),
        cpus_per_node=spec.cluster.cpus_per_node,
        cache_policy=EvictionPolicy(spec.cache.eviction),
        cache_capacity_bytes=spec.cache.capacity_bytes,
        caching_enabled=spec.cache.enabled,
        write_outputs_to=spec.write_outputs_to,
        index_update_interval_s=spec.index_update_interval_s,
        release_policy=spec.release_policy,
        flow_solver=spec.flow_solver,
        speculation_factor=spec.speculation_factor,
        provisioner=provisioner,
        provisioner_period_s=(spec.provisioner.period_s
                              if spec.provisioner else 1.0),
        seed=spec.seed)


#: store payload for shape-only runs (no task_fn); lives in core.runtime
#: since the fleet wire protocol gives it a stable encoding.
_SHAPE_ONLY_PAYLOAD = SHAPE_ONLY_PAYLOAD


def build_recorder(spec: ExperimentSpec) -> Optional[Recorder]:
    """The engine-side half of ``spec.observe``: None with events off (the
    hot paths then carry only a None-check), a bounded drop-oldest ring
    otherwise.  Both adapters install the same object on their dispatcher,
    runtime/sim and provisioner, so one ring holds the whole run."""
    if not spec.observe.events:
        return None
    return Recorder(spec.observe.ring_capacity)


def build_telemetry(spec: ExperimentSpec):
    """The engine-side half of ``spec.observe.metrics_*`` (DESIGN.md §13):
    None with telemetry off, otherwise a `Telemetry` bundle with a
    `HealthMonitor` attached.  Engines hand ``bundle.registry`` to the hot
    paths and call ``record_sample`` on their own clock."""
    if not spec.observe.metrics:
        return None
    from repro.obs import HealthMonitor, Telemetry

    return Telemetry(interval_s=spec.observe.metrics_interval_s,
                     sink_path=spec.observe.metrics_sink_path,
                     health=HealthMonitor())


def _telemetry_summary(telemetry, recorder: Optional[Recorder]) -> dict:
    """The RunReport.telemetry payload: the final central snapshot, the
    final per-host snapshots and their cluster fold, the health-event log,
    and the recorder drop count.  ``recorder_dropped`` is included whenever
    a recorder ran -- even with the metrics plane off -- so a truncated
    event ring is never silent (tools/run_experiment.py warns on it)."""
    out: dict = {}
    if recorder is not None:
        out["recorder_dropped"] = recorder.dropped
    if telemetry is not None:
        last = telemetry.series[-1] if telemetry.series else {}
        out["metrics"] = last.get("metrics", {})
        out["hosts"] = last.get("hosts", {})
        out["merged"] = telemetry.merged_last()
        out["n_samples"] = len(telemetry.series)
        out["health_events"] = list(telemetry.health_events)
    return out


def _finish_observe(spec: ExperimentSpec, recorder: Optional[Recorder]) -> None:
    """Post-run sink: dump the ring to ``observe.sink_path`` if bound."""
    if recorder is not None and spec.observe.sink_path is not None:
        recorder.dump(spec.observe.sink_path)


def _reject(engine: str, knob: str, value, supported) -> None:
    raise ValueError(
        f"spec sets {knob}={value!r}, which the {engine} engine does not "
        f"support (it honours {knob} only as {supported}; see "
        f"repro.experiments.spec.ALIASES).  Refusing to run rather than "
        f"silently ignoring the knob.")


# --------------------------------------------------------------------------
# the Engine protocol + adapters
# --------------------------------------------------------------------------

@runtime_checkable
class Engine(Protocol):
    """prepare(spec) -> run(**kw) -> RunReport -> shutdown()."""

    name: str

    def prepare(self, spec: ExperimentSpec,
                workload: Optional[Workload] = None) -> "Engine": ...

    def run(self, **kwargs) -> RunReport: ...

    def shutdown(self) -> None: ...


class SimEngine:
    """Discrete-event engine adapter.  After ``run()``, ``self.sim`` /
    ``self.result`` / ``self.metrics`` stay available for deep inspection
    (flow logs, dispatcher state)."""

    name = "sim"

    def __init__(self) -> None:
        self.spec: Optional[ExperimentSpec] = None
        self.sim: Optional[DiffusionSim] = None
        self.workload: Optional[Workload] = None
        self.provisioner: Optional[DynamicResourceProvisioner] = None
        self.recorder: Optional[Recorder] = None
        self.telemetry = None
        self.tel_server = None
        self.last_outcomes: Optional[list[dict]] = None
        self.result = None
        self.metrics = None

    def prepare(self, spec: ExperimentSpec,
                workload: Optional[Workload] = None) -> "SimEngine":
        check_alias_map()
        if spec.index_update_batch != 1:
            _reject("sim", "index_update_batch", spec.index_update_batch,
                    "the runtime's loose-coherence knob "
                    "(sim uses index_update_interval_s)")
        if spec.hosts != 0:
            _reject("sim", "hosts", spec.hosts,
                    "0 (process layout is a threaded-runtime concern; the "
                    "simulator has no OS processes to spread over)")
        self.spec = spec
        self.provisioner = (build_provisioner(spec.provisioner)
                            if spec.provisioner else None)
        self.recorder = build_recorder(spec)
        self.cfg = build_sim_config(spec, self.provisioner)
        # installed on the config BEFORE construction: the sim ctor swaps
        # the recorder clock to the simulated clock and hands the recorder
        # to its dispatcher
        self.cfg.recorder = self.recorder
        if self.provisioner is not None:
            self.provisioner.recorder = self.recorder
        # telemetry rides the same pre-construction path: the sim ctor
        # installs the registry on its dispatcher/provisioner and schedules
        # the virtual-time sampling tick
        self.telemetry = build_telemetry(spec)
        self.cfg.metrics = self.telemetry
        if self.telemetry is not None and spec.observe.metrics_port >= 0:
            from repro.obs import TelemetryServer

            self.tel_server = TelemetryServer(self.telemetry,
                                              port=spec.observe.metrics_port)
        self.sim = DiffusionSim(self.cfg)
        self.workload = workload if workload is not None \
            else build_workload(spec.workload)
        return self

    def run(self, until: float = float("inf")) -> RunReport:
        if self.sim is None:
            raise RuntimeError("call prepare(spec) before run()")
        t0 = time.perf_counter()
        self.sim.submit_workload(self.workload)
        r = self.sim.run(until)
        wall = time.perf_counter() - t0
        tb = TESTBEDS[self.spec.cluster.testbed]
        m = MetricsCollector(tb, cpus_per_node=self.cfg.cpus_per_node).collect(
            r, n_submitted=self.sim.n_submitted)
        self.result, self.metrics = r, m
        # measured (here: simulated) per-task outcomes -- sim clocks are
        # already run-relative, no rebasing
        self.last_outcomes = [outcome_record(t) for t in r.dispatcher.completed]
        _finish_observe(self.spec, self.recorder)
        telemetry = None
        if self.telemetry is not None:
            # one settled final sample at the virtual end time, so the
            # report's snapshot reconciles exactly with the run's totals
            self.sim.sample_metrics()
            self.telemetry.record_sample(self.sim.loop.now)
            telemetry = _telemetry_summary(self.telemetry, self.recorder)
            self.telemetry.close()
        elif self.recorder is not None:
            telemetry = _telemetry_summary(None, self.recorder)
        prov = self.provisioner
        return build_report(
            self.spec, self.name, r, m, wall_s=wall,
            n_allocated=prov.n_allocated if prov else 0,
            n_released=prov.n_released if prov else 0,
            telemetry=telemetry)

    def shutdown(self) -> None:
        """Close the status endpoint if one was bound (the event loop owns
        no threads of its own)."""
        if self.tel_server is not None:
            self.tel_server.close()
            self.tel_server = None


class _ProvisionerDriver(threading.Thread):
    """Wall-clock DRP tick loop for the threaded runtime: the counterpart
    of `DiffusionSim._provision_tick`.  The spec's provisioner times
    (period, idle timeout, cooldown) are workload seconds, mapped onto the
    wall clock by ``time_scale`` exactly like arrival pacing -- all three
    scale together, so sim and runtime release on the same workload clock.
    With ``time_scale=0`` (as-fast-as-possible) there is no workload clock
    and the raw values are used as wall seconds.  Executor startup is
    immediate (threads, not cluster nodes)."""

    def __init__(self, rt: DiffusionRuntime,
                 prov: DynamicResourceProvisioner, period_s: float) -> None:
        super().__init__(daemon=True, name="runtime-provisioner")
        self.rt, self.prov = rt, prov
        self.period_s = max(period_s, 0.01)
        self.stop_evt = threading.Event()

    def run(self) -> None:
        while not self.stop_evt.wait(self.period_s):
            now = time.monotonic()
            with self.rt._lock:
                queue_len = self.rt.dispatcher.queue_len
                live = len(self.rt.workers)
                idle = self.rt.provision_idle(now, self.prov.idle_timeout_s)
            acts = self.prov.step(now, queue_len, live, 0, idle)
            # granularity is the runtime's business: thread executors in
            # process, whole hosts (threads_per_host executors each) on a
            # fleet -- same driver either way.  A failed grow (e.g. a fleet
            # host that never connects) must not unwind this daemon thread:
            # provisioning silently stopping for the rest of the run is
            # strictly worse than one missed allocation.
            try:
                self.rt.provision_grow(acts.allocate)
                self.rt.provision_release(acts.release)
            except Exception as e:  # noqa: BLE001
                print(f"runtime-provisioner: provisioning action failed "
                      f"({type(e).__name__}: {e}); continuing",
                      file=sys.stderr)

    def stop(self) -> None:
        self.stop_evt.set()


class _TelemetrySampler(threading.Thread):
    """Wall-clock telemetry tick for the threaded runtime (counterpart of
    `DiffusionSim._metrics_tick`): every ``telemetry.interval_s`` it
    refreshes the runtime's gauges, lets the engine add its own
    (`_engine_gauges`), folds in the fleet's per-host cluster view when
    there is one, and records one sample stamped in run-relative seconds."""

    def __init__(self, eng: "RuntimeEngine", t0: float) -> None:
        super().__init__(daemon=True, name="telemetry-sampler")
        self.eng = eng
        self.t0 = t0
        self.stop_evt = threading.Event()

    def sample_once(self) -> None:
        eng = self.eng
        eng.runtime.sample_metrics()
        eng._engine_gauges()
        per_host = None
        manager = getattr(eng.runtime, "manager", None)
        if manager is not None:
            per_host = manager.cluster.per_host()
        eng.telemetry.record_sample(time.monotonic() - self.t0,
                                    per_host=per_host)

    def run(self) -> None:
        while not self.stop_evt.wait(self.eng.telemetry.interval_s):
            self.sample_once()

    def stop(self) -> None:
        self.stop_evt.set()


class RuntimeEngine:
    """Threaded-runtime adapter.  ``run()`` paces the workload in (see
    `DiffusionRuntime.submit_workload`), drains it, and reports in wall
    seconds.  ``self.runtime`` stays alive afterwards for payload/result
    inspection; call :meth:`shutdown` when done.

    ``spec.hosts > 0`` selects fleet mode: the SAME adapter drives a
    `repro.fleet.FleetRuntime` (executors spread over OS processes) --
    placement, accounting and the report pipeline are identical, only the
    pool's process layout changes.  Task callables cannot cross process
    boundaries, so fleet runs take ``task_fn_name`` (resolved host-side
    against ``repro.fleet.TASK_FNS`` or as ``module:attr``) instead of
    ``run(task_fn=...)``."""

    name = "runtime"

    def __init__(self, task_fn_name: Optional[str] = None) -> None:
        self.spec: Optional[ExperimentSpec] = None
        self.runtime: Optional[DiffusionRuntime] = None
        self.workload: Optional[Workload] = None
        self.provisioner: Optional[DynamicResourceProvisioner] = None
        self.task_fn_name = task_fn_name
        self._driver: Optional[_ProvisionerDriver] = None
        self.recorder: Optional[Recorder] = None
        self.telemetry = None
        self.tel_server = None
        self._sampler: Optional[_TelemetrySampler] = None
        self.last_outcomes: Optional[list[dict]] = None
        self.result = None
        self.metrics = None

    def prepare(self, spec: ExperimentSpec,
                workload: Optional[Workload] = None) -> "RuntimeEngine":
        check_alias_map()
        if spec.cluster.cpus_per_node != 1:
            _reject("runtime", "cluster.cpus_per_node",
                    spec.cluster.cpus_per_node, "1 (workers are 1-slot)")
        if spec.write_outputs_to != "local":
            _reject("runtime", "write_outputs_to", spec.write_outputs_to,
                    "'local' (outputs land in the worker cache)")
        if spec.index_update_interval_s != 0.0:
            _reject("runtime", "index_update_interval_s",
                    spec.index_update_interval_s,
                    "0.0 (the runtime batches by count: index_update_batch)")
        if spec.release_policy != "discard":
            _reject("runtime", "release_policy", spec.release_policy,
                    "'discard' (removed workers drop their caches)")
        if spec.flow_solver != "incremental":
            _reject("runtime", "flow_solver", spec.flow_solver,
                    "'incremental' (there is no fluid-flow clock)")
        if spec.speculation_factor != 0.0:
            _reject("runtime", "speculation_factor", spec.speculation_factor,
                    "0.0 (no speculative twins in the threaded runtime)")
        self.spec = spec
        self.recorder = build_recorder(spec)
        self.telemetry = build_telemetry(spec)
        if self.telemetry is not None and spec.observe.metrics_port >= 0:
            from repro.obs import TelemetryServer

            self.tel_server = TelemetryServer(self.telemetry,
                                              port=spec.observe.metrics_port)
        if spec.hosts > 0:
            from repro.fleet import FleetRuntime

            self.runtime = FleetRuntime(
                hosts=spec.hosts,
                threads_per_host=spec.threads_per_host,
                policy=DispatchPolicy(spec.policy),
                cache_policy=EvictionPolicy(spec.cache.eviction),
                cache_capacity_bytes=(spec.cache.capacity_bytes
                                      if spec.cache.enabled else 0),
                seed=spec.seed,
                index_update_batch=spec.index_update_batch,
                wire_batch=spec.wire_batch,
                local_dispatch=spec.local_dispatch,
                task_fn_name=self.task_fn_name,
                recorder=self.recorder,
                metrics=self.telemetry)
        else:
            self.runtime = DiffusionRuntime(
                n_executors=spec.cluster.n_nodes,
                policy=DispatchPolicy(spec.policy),
                cache_policy=EvictionPolicy(spec.cache.eviction),
                cache_capacity_bytes=(spec.cache.capacity_bytes
                                      if spec.cache.enabled else 0),
                seed=spec.seed,
                index_update_batch=spec.index_update_batch,
                recorder=self.recorder,
                metrics=self.telemetry)
        self.workload = workload if workload is not None \
            else build_workload(spec.workload)
        return self

    def run(self, *,
            task_fn: Optional[Callable[..., Any]] = None,
            payload_factory: Optional[Callable[[DataObject], Any]] = None,
            time_scale: float = 0.0,
            timeout: float = 600.0,
            barrier_every: Optional[int] = None) -> RunReport:
        rt = self.runtime
        if rt is None:
            raise RuntimeError("call prepare(spec) before run()")
        if task_fn is not None and self.spec.hosts > 0:
            raise ValueError(
                "fleet runs cannot ship a task callable over the wire; "
                "construct RuntimeEngine(task_fn_name=...) so each host "
                "resolves it from repro.fleet.TASK_FNS / module:attr")
        if task_fn is None and self.task_fn_name and self.spec.hosts == 0:
            # the named-callable surface works identically on the thread
            # pool (resolved here) and the fleet (resolved host-side) --
            # silently dropping the name would run every task shape-only
            from repro.fleet.host import resolve_task_fn

            task_fn = resolve_task_fn(self.task_fn_name)
        if payload_factory is None:
            # shape-only runs (no task_fn) still need store payloads to
            # resolve; byte accounting uses DataObject sizes, not payloads
            payload_factory = lambda ob: _SHAPE_ONLY_PAYLOAD  # noqa: E731
        if self.spec.provisioner is not None:
            # DRP built here, not in prepare(): its time knobs depend on
            # this run's time_scale (see _ProvisionerDriver docstring).
            # Scale the spec, then reuse build_provisioner -- one
            # construction path, so new ProvisionerSpec fields cannot
            # silently diverge between engines.
            ps = self.spec.provisioner
            ts = time_scale if time_scale > 0 else 1.0
            self.provisioner = build_provisioner(
                dataclasses.replace(
                    ps, idle_timeout_s=ps.idle_timeout_s * ts,
                    trigger_cooldown_s=ps.trigger_cooldown_s * ts),
                allocate_quantum=(self.spec.threads_per_host
                                  if self.spec.hosts > 0 else 1))
            self.provisioner.recorder = self.recorder
            self._driver = _ProvisionerDriver(rt, self.provisioner,
                                              ps.period_s * ts)
            self._driver.start()
        t0 = time.monotonic()
        if self.telemetry is not None:
            self._sampler = _TelemetrySampler(self, t0)
            self._sampler.start()
        submitter = rt.submit_workload(
            self.workload, task_fn=task_fn,
            payload_factory=payload_factory, time_scale=time_scale,
            barrier_every=barrier_every)
        submitter.join(timeout)
        drained = (not submitter.is_alive()
                   and rt.wait(max(timeout - (time.monotonic() - t0), 0.01)))
        if self._driver is not None:
            self._driver.stop()
            self._driver.join(5.0)
        if self._sampler is not None:
            self._sampler.stop()
            self._sampler.join(5.0)
        if not drained:
            rt.shutdown()
            raise TimeoutError(
                f"runtime run of {self.spec.name!r} did not drain within "
                f"{timeout}s ({len(rt.dispatcher.completed)} completed)")
        wall = time.monotonic() - t0
        r = self._result_view(t_run0=t0, t_end=time.monotonic())
        tb = TESTBEDS[self.spec.cluster.testbed]
        m = MetricsCollector(tb, cpus_per_node=1).collect(
            r, n_submitted=len(self.workload))
        self.result, self.metrics = r, m
        # measured per-task outcomes, rebased from the monotonic clock to
        # seconds since run() started (same base as _result_view)
        self.last_outcomes = [outcome_record(t, base=t0)
                              for t in rt.dispatcher.completed]
        _finish_observe(self.spec, self.recorder)
        telemetry = None
        if self.telemetry is not None:
            # settled final sample: on a fleet, first barrier on a fresh
            # post-drain stats frame from every live host so the per-host
            # snapshots in the report reflect the finished run exactly
            if hasattr(rt, "request_stats"):
                rt.request_stats()
            self._sampler.sample_once()
            telemetry = _telemetry_summary(self.telemetry, self.recorder)
            self.telemetry.close()
        elif self.recorder is not None:
            telemetry = _telemetry_summary(None, self.recorder)
        prov = self.provisioner
        return build_report(
            self.spec, self.name, r, m, wall_s=wall,
            n_allocated=prov.n_allocated if prov else 0,
            n_released=prov.n_released if prov else 0,
            dispatch_stats=rt.dispatch_stats(),
            telemetry=telemetry)

    def _engine_gauges(self) -> None:
        """Subclass hook: extra engine-specific gauges per telemetry tick
        (the serve engine reports its KV-reuse byte split here)."""

    def _result_view(self, t_run0: float, t_end: float) -> SimResult:
        """The runtime's observables in `SimResult` shape, with every clock
        rebased to seconds since ``run()`` started (NOT since runtime
        construction -- the prepare->run gap, e.g. workload generation,
        must not inflate makespan or the pool integral), so
        MetricsCollector -- and therefore every RunReport formula -- is
        shared with the sim."""
        rt = self.runtime
        offset = t_run0 - rt._t0   # pool_log times are construction-relative
        d = rt.dispatcher
        lg = rt.ledger
        starts = [t.start_time for t in d.completed]
        ends = [t.end_time for t in d.completed]
        return SimResult(
            makespan=t_end - t_run0,
            t_first_dispatch=(min(starts) - t_run0) if starts else 0.0,
            t_last_complete=(max(ends) - t_run0) if ends else 0.0,
            bytes_by_kind={"local": float(lg.bytes_local),
                           "c2c": float(lg.bytes_c2c),
                           "store_read": float(lg.bytes_store)},
            n_completed=len(d.completed),
            n_failed=len(d.failed),
            local_hits=lg.local_hits,
            peer_hits=lg.peer_hits,
            store_reads=lg.store_reads,
            dispatcher=d,
            flow_log=[],
            pool_log=[(max(t - offset, 0.0), n) for t, n in rt.pool_log],
        )

    def shutdown(self) -> None:
        if self._driver is not None:
            self._driver.stop()
        if self._sampler is not None:
            self._sampler.stop()
        if self.tel_server is not None:
            self.tel_server.close()
            self.tel_server = None
        if self.runtime is not None:
            self.runtime.shutdown()


#: engine registry (CLI + sweep runner bind engines by name)
ENGINES: dict[str, type] = {"sim": SimEngine, "runtime": RuntimeEngine}

#: engines living outside repro.experiments, resolved on first use --
#: repro.serve.diffusion imports back into this module, so registering its
#: class eagerly would be a cycle.  Value = (module, class name).
LAZY_ENGINES: dict[str, tuple[str, str]] = {
    "serve": ("repro.serve.diffusion", "ServeDiffusionEngine"),
}


def engine_names() -> list[str]:
    """Every engine name make_engine accepts (CLI choices lists)."""
    return sorted([*ENGINES, *LAZY_ENGINES])


def make_engine(name: str):
    if name in ENGINES:
        return ENGINES[name]()
    if name in LAZY_ENGINES:
        import importlib

        module, cls = LAZY_ENGINES[name]
        return getattr(importlib.import_module(module), cls)()
    raise ValueError(f"unknown engine {name!r} (known: {engine_names()})")


def run_experiment(spec: ExperimentSpec, engine: str = "sim",
                   workload: Optional[Workload] = None, **run_kw) -> RunReport:
    """One-shot convenience: build the named engine, prepare, run.

    An engine named by string is owned here and shut down before
    returning (the threaded runtime's workers must not outlive the run);
    pass an engine *instance* instead to keep it alive for inspection.
    """
    owned = isinstance(engine, str)
    eng = make_engine(engine) if owned else engine
    try:
        eng.prepare(spec, workload=workload)
        return eng.run(**run_kw)
    finally:
        if owned:
            eng.shutdown()

"""Batched serving engine: prefill + decode over the model substrate, with
prefix-aware routing across replicas.

Single-process, R replica states of one small model (the serving analogue
of the threaded diffusion runtime): requests are routed by
PrefixAwareRouter, prefilled (reusing cached prefix KV when the router
found one), then batch-decoded.  Real-fleet note: each ReplicaEngine maps
to a model server; routing/index messages are the RPC seam.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import EvictionPolicy
from repro.core.policies import DispatchPolicy
from repro.models import init_cache, init_params, make_serve_step
from repro.models.config import ModelConfig
from repro.models.model import make_forward
from .kvcache import kv_bytes_per_token
from .router import PrefixAwareRouter


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    output: list[int] = field(default_factory=list)
    replica: str = ""
    reused_tokens: int = 0


class ServeEngine:
    """R logical replicas sharing one set of weights (single process)."""

    def __init__(self, cfg: ModelConfig, n_replicas: int = 2,
                 policy: DispatchPolicy = DispatchPolicy.MAX_COMPUTE_UTIL,
                 cache_policy: EvictionPolicy = EvictionPolicy.LRU,
                 replica_cache_bytes: int = 1 << 26,
                 max_seq: int = 256, seed: int = 0) -> None:
        self.cfg = cfg
        self.max_seq = max_seq
        self.params = init_params(cfg, jax.random.PRNGKey(seed))
        self.router = PrefixAwareRouter(
            n_replicas, policy, cache_policy, replica_cache_bytes,
            kv_bytes_per_token=max(kv_bytes_per_token(cfg), 1),
            block=16, slots_per_replica=8)
        self._fwd = jax.jit(make_forward(cfg))
        self._step = jax.jit(make_serve_step(cfg))
        self.prefill_tokens = 0
        self.reused_tokens = 0

    # -- greedy generation for a batch of requests ------------------------
    def generate(self, requests: Sequence[Request]) -> list[Request]:
        for r in requests:
            route = self.router.route(r.prompt)
            r.replica = route.replica
            r.reused_tokens = route.reused_prefix_tokens
            self.reused_tokens += route.reused_prefix_tokens
            # prefill cost is only the non-reused suffix (the paper's
            # cache-hit economics: bytes NOT refetched == tokens NOT recomputed)
            self.prefill_tokens += max(len(r.prompt) - route.reused_prefix_tokens, 0)
        # batch all requests together (single-process simplification)
        B = len(requests)
        S = self.max_seq
        toks = np.zeros((B, S), np.int32)
        lens = np.array([len(r.prompt) for r in requests])
        for i, r in enumerate(requests):
            toks[i, : lens[i]] = r.prompt
        logits, _ = self._fwd(self.params, {"tokens": jnp.asarray(toks)})
        cache = init_cache(self.cfg, B, S)
        # prefill the cache by replaying tokens through serve_step (keeps
        # one decode path -- correctness tested against the fwd logits)
        pos_logits = None
        for t in range(int(lens.max())):
            step_tok = jnp.asarray(toks[:, t: t + 1])
            pos_logits, cache = self._step(self.params, cache,
                                           {"token": step_tok,
                                            "pos": jnp.int32(t)})
        # greedy decode
        cur = jnp.argmax(pos_logits[:, -1], axis=-1).astype(jnp.int32)
        max_new = max(r.max_new_tokens for r in requests)
        for j in range(max_new):
            for i, r in enumerate(requests):
                if j < r.max_new_tokens:
                    r.output.append(int(cur[i]))
            pos = int(lens.max()) + j
            if pos >= S:
                break
            lg, cache = self._step(self.params, cache,
                                   {"token": cur[:, None],
                                    "pos": jnp.int32(pos)})
            cur = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
        from .router import RouteResult
        for r in requests:
            self.router.complete(r.prompt, RouteResult(
                replica=r.replica, reused_prefix_tokens=r.reused_tokens,
                reused_bytes=0))
        return list(requests)

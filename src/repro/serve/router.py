"""Prefix-cache-aware request routing = the paper's data-aware scheduling
applied to serving replicas.

Mapping (DESIGN.md §2/§12): replica == executor, cached prefix-KV page ==
cached file, request == task whose inputs are the block-aligned prefixes of
its prompt.  The four dispatch policies transfer verbatim:

  first-available       round-robin-ish, no prefix reuse information
  first-cache-available route anywhere but ship prefix locations (replica
                        may pull KV from a peer replica)
  max-cache-hit         wait for the replica with the longest cached prefix
  max-compute-util      among FREE replicas pick the longest cached prefix
                        (modern prefix-aware load balancing)

Scoring is delegated wholesale to :func:`repro.core.policies.decide` -- the
SAME pure function the Dispatcher's ``_dispatch_mcu`` reduces to for a
single queued task -- so the router cannot drift from core policy
semantics (regression-locked by repro.serve.diffusion.reference against a
real Dispatcher).  Tie-break order matches ``_dispatch_mcu``: cached bytes
descending, then overlap fraction, then queue position.  For ONE prompt the
overlap-fraction denominator (the task's own input byte total) is the same
at every replica, so that middle tie-break is vacuous here and ties fall
through to position -- ``decide``'s first-max over replicas in registration
order, exactly the dispatcher's ``_exec_order``.

Sizing: each prefix-chain oid is ONE KV *page* of ``block *
kv_bytes_per_token`` bytes (the vLLM paged-KV shape: the page is
content-addressed by the whole prefix up to its block, but stores only that
block's KV).  A replica caching an m-page chain therefore scores exactly
m * page_bytes == the KV bytes a hit actually reuses.  (The previous
cumulative sizing -- page i sized as the whole i-block prefix -- double-
counted shared blocks O(m^2) and skewed every policy toward long chains.)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.core.cache import EvictionPolicy, ExecutorCache
from repro.core.index import LocationIndex
from repro.core.objects import DataObject, Task
from repro.core.policies import DispatchPolicy, decide
from .kvcache import prefix_chain


@dataclass
class ReplicaState:
    rid: str
    cache: ExecutorCache
    busy: int = 0
    slots: int = 4
    served: int = 0

    @property
    def available(self) -> bool:
        return self.busy < self.slots


@dataclass
class RouteResult:
    replica: str
    reused_prefix_tokens: int
    reused_bytes: int
    hints: dict[str, tuple[str, ...]] = field(default_factory=dict)


class PrefixAwareRouter:
    def __init__(
        self,
        n_replicas: int,
        policy: DispatchPolicy = DispatchPolicy.MAX_COMPUTE_UTIL,
        cache_policy: EvictionPolicy = EvictionPolicy.LRU,
        replica_cache_bytes: int = 1 << 30,
        kv_bytes_per_token: int = 1 << 12,
        block: int = 64,
        slots_per_replica: int = 4,
    ) -> None:
        self.policy = policy
        self.block = block
        self.kv_bpt = kv_bytes_per_token
        self.index = LocationIndex()
        self.replicas: dict[str, ReplicaState] = {}
        self.sizes: dict[str, int] = {}
        self._order: list[str] = []
        for i in range(n_replicas):
            rid = f"r{i}"
            self.replicas[rid] = ReplicaState(
                rid, ExecutorCache(replica_cache_bytes, cache_policy, seed=i),
                slots=slots_per_replica)
            self._order.append(rid)

    @property
    def page_bytes(self) -> int:
        """KV bytes of one prefix page (== one chain oid)."""
        return self.block * self.kv_bpt

    # ------------------------------------------------------------------
    def route(self, prompt: Sequence[int]) -> RouteResult:
        """Pick a replica for a prompt; caller must later call
        ``complete`` with the same result."""
        oids = prefix_chain(prompt, self.block)
        for oid in oids:
            self.sizes.setdefault(oid, self.page_bytes)
        task = Task(inputs=tuple(oids))
        avail = [r for r in self._order if self.replicas[r].available]
        busy = [r for r in self._order if not self.replicas[r].available]
        d = decide(self.policy, task, avail, busy, self.index, self.sizes)
        # decide() may return neither an executor nor a wait_for target
        # (every replica saturated under FA/FCA/MCU, or nothing cached and
        # nobody free under MCH).  A serving front-end cannot leave the
        # request unplaced, so fall back to the least-loaded replica
        # (registration order breaks ties) -- NOT r0, which would pile the
        # whole overload onto one replica.
        rid = d.executor or d.wait_for or self._least_busy()
        rep = self.replicas[rid]
        rep.busy += 1
        # longest cached block-prefix ON the chosen replica
        reused = 0
        for i, oid in enumerate(oids):
            if oid in rep.cache:
                rep.cache.get(oid)  # recency touch
                reused = (i + 1) * self.block
            else:
                break
        return RouteResult(replica=rid, reused_prefix_tokens=reused,
                           reused_bytes=reused * self.kv_bpt, hints=d.hints)

    def _least_busy(self) -> str:
        """Overload fallback: fewest in-flight requests, ties by
        registration order (min() keeps the first minimum)."""
        return min(self._order, key=lambda r: self.replicas[r].busy)

    def complete(self, prompt: Sequence[int], result: RouteResult) -> None:
        """Request finished: register the full prefix chain in the
        replica's cache + the central index (loose coherence)."""
        rep = self.replicas[result.replica]
        rep.busy = max(rep.busy - 1, 0)
        rep.served += 1
        for oid in prefix_chain(prompt, self.block):
            evicted = rep.cache.put(DataObject(oid, self.sizes[oid]))
            self.index.insert(oid, rep.rid)
            for ev in evicted:
                self.index.remove(ev, rep.rid)

    # ------------------------------------------------------------------
    def reference_scores(self, prompt: Sequence[int]) -> dict[str, int]:
        """Brute-force replica -> cached-input-bytes for ``prompt``,
        rebuilt from fresh index lookups -- the router-side analogue of
        ``Dispatcher.reference_scores()`` and the oracle the regression
        lock (repro.serve.diffusion.reference, tests) compares against."""
        scores = {rid: 0 for rid in self._order}
        for oid in dict.fromkeys(prefix_chain(prompt, self.block)):
            sz = self.sizes.get(oid, 1)
            for rid in self.index.lookup(oid):
                if rid in scores:
                    scores[rid] += sz
        return scores

    def stats(self) -> dict:
        served = sum(r.served for r in self.replicas.values())
        return {
            "served": served,
            "per_replica": {r.rid: r.served for r in self.replicas.values()},
            "index_entries": len(self.index),
        }

"""Prefix-cache-aware request routing = the paper's data-aware scheduling
applied to serving replicas.

Mapping (DESIGN.md §2): replica == executor, cached prefix KV == cached
file, request == task whose inputs are the block-aligned prefixes of its
prompt.  The four dispatch policies transfer verbatim:

  first-available       round-robin-ish, no prefix reuse information
  first-cache-available route anywhere but ship prefix locations (replica
                        may pull KV from a peer replica)
  max-cache-hit         wait for the replica with the longest cached prefix
  max-compute-util      among FREE replicas pick the longest cached prefix
                        (modern prefix-aware load balancing)

The router scores by *bytes of KV reused* because the Dispatcher's
max-policies weight hints by object size -- longer prefixes win, exactly
like larger files did in the paper.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.core.cache import EvictionPolicy, ExecutorCache
from repro.core.index import LocationIndex
from repro.core.objects import DataObject, Task
from repro.core.policies import DispatchPolicy, decide
from .kvcache import prefix_chain, prefix_oid


@dataclass
class ReplicaState:
    rid: str
    cache: ExecutorCache
    busy: int = 0
    slots: int = 4
    served: int = 0

    @property
    def available(self) -> bool:
        return self.busy < self.slots


@dataclass
class RouteResult:
    replica: str
    reused_prefix_tokens: int
    reused_bytes: int
    hints: dict[str, tuple[str, ...]] = field(default_factory=dict)


class PrefixAwareRouter:
    def __init__(
        self,
        n_replicas: int,
        policy: DispatchPolicy = DispatchPolicy.MAX_COMPUTE_UTIL,
        cache_policy: EvictionPolicy = EvictionPolicy.LRU,
        replica_cache_bytes: int = 1 << 30,
        kv_bytes_per_token: int = 1 << 12,
        block: int = 64,
        slots_per_replica: int = 4,
    ) -> None:
        self.policy = policy
        self.block = block
        self.kv_bpt = kv_bytes_per_token
        self.index = LocationIndex()
        self.replicas: dict[str, ReplicaState] = {}
        self.sizes: dict[str, int] = {}
        self._order: list[str] = []
        for i in range(n_replicas):
            rid = f"r{i}"
            self.replicas[rid] = ReplicaState(
                rid, ExecutorCache(replica_cache_bytes, cache_policy, seed=i),
                slots=slots_per_replica)
            self._order.append(rid)

    # ------------------------------------------------------------------
    def route(self, prompt: Sequence[int]) -> RouteResult:
        """Pick a replica for a prompt; caller must later call
        ``complete`` with the same result."""
        oids = prefix_chain(prompt, self.block)
        for i, oid in enumerate(oids):
            self.sizes.setdefault(oid, (i + 1) * self.block * self.kv_bpt)
        task = Task(inputs=tuple(oids))
        avail = [r for r in self._order if self.replicas[r].available]
        busy = [r for r in self._order if not self.replicas[r].available]
        d = decide(self.policy, task, avail, busy, self.index, self.sizes)
        rid = d.executor or d.wait_for or (avail[0] if avail else self._order[0])
        rep = self.replicas[rid]
        rep.busy += 1
        # longest cached block-prefix ON the chosen replica
        reused = 0
        for i, oid in enumerate(oids):
            if oid in rep.cache:
                rep.cache.get(oid)  # recency touch
                reused = (i + 1) * self.block
            else:
                break
        return RouteResult(replica=rid, reused_prefix_tokens=reused,
                           reused_bytes=reused * self.kv_bpt, hints=d.hints)

    def complete(self, prompt: Sequence[int], result: RouteResult) -> None:
        """Request finished: register the full prefix chain in the
        replica's cache + the central index (loose coherence)."""
        rep = self.replicas[result.replica]
        rep.busy = max(rep.busy - 1, 0)
        rep.served += 1
        for oid in prefix_chain(prompt, self.block):
            evicted = rep.cache.put(DataObject(oid, self.sizes[oid]))
            self.index.insert(oid, rep.rid)
            for ev in evicted:
                self.index.remove(ev, rep.rid)

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        served = sum(r.served for r in self.replicas.values())
        return {
            "served": served,
            "per_replica": {r.rid: r.served for r in self.replicas.values()},
            "index_entries": len(self.index),
        }

"""Prefix/KV cache objects for serving.

The paper's immutable-data assumption holds exactly for prefix caches:
a computed prefix KV is content-addressed by its token hash and never
mutated -- so the diffusion machinery (per-replica ExecutorCache with
Random/FIFO/LRU/LFU eviction + central location index) applies verbatim.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Optional, Sequence

from repro.core.objects import DataObject


def prefix_oid(tokens: Sequence[int]) -> str:
    """Content address of a token prefix."""
    h = hashlib.sha1(bytes(str(tuple(tokens)), "utf8")).hexdigest()[:16]
    return f"prefix:{h}:{len(tokens)}"


def prefix_chain(tokens: Sequence[int], block: int = 64) -> list[str]:
    """oids for every block-aligned prefix of ``tokens`` (longest last)."""
    out = []
    for end in range(block, len(tokens) + 1, block):
        out.append(prefix_oid(tokens[:end]))
    return out


def kv_bytes_per_token(cfg) -> int:
    """KV-cache bytes per token for a ModelConfig (bf16)."""
    total = 0
    for spec in cfg.pattern:
        if spec.kind == "attn":
            total += 2 * cfg.n_kv_heads * cfg.head_dim_ * 2
    return total * cfg.n_blocks


@dataclass
class PrefixEntry:
    """A cached prefix: token ids + the packed KV payload."""
    oid: str
    tokens: tuple[int, ...]
    payload: Any           # model KV pytree (or None for accounting-only)
    size_bytes: int

    def as_object(self) -> DataObject:
        return DataObject(self.oid, self.size_bytes)

"""Regression lock: a Dispatcher twin must predict the router's choices.

``dispatcher_twin`` rebuilds a REAL `repro.core.scheduler.Dispatcher` from
the router's observable state (replica order, busy counts, a fresh copy of
the location index, the page sizes) and submits the prompt as the Task the
session workload would emit.  Two independent reconstructions then have to
agree with the router:

  scores     the twin's brute-force ``Dispatcher.reference_scores()``
             (executor -> cached input bytes for the queued probe) must
             equal ``PrefixAwareRouter.reference_scores(prompt)`` entry
             for entry -- the satellite's literal lock;
  placement  ``decide()`` over the twin's reconstructed avail/busy/index
             must name the replica the router routes to.  decide() is the
             single-task reduction of the dispatcher's fifo path and of
             ``_dispatch_mcu``'s scoring (bytes desc, then overlap
             fraction -- vacuous for one prompt, see router.py -- then
             queue position).  NB `_dispatch_mcu` itself is executor-
             centric: with NO backlog it hands a lone task to the first
             free executor, because its matching is designed for the
             backlogged regime where each executor picks its best among
             many.  The router serves the task-centric regime, so the
             placement oracle is decide(), not a drained next_dispatches.

Any private drift in the router (stale index entries, size bookkeeping,
availability accounting) breaks one of the two comparisons.
"""
from __future__ import annotations

from typing import Sequence

from repro.core.objects import Task
from repro.core.policies import decide
from repro.core.scheduler import Dispatcher

from ..kvcache import prefix_chain
from ..router import PrefixAwareRouter, RouteResult


def dispatcher_twin(router: PrefixAwareRouter) -> Dispatcher:
    """A real Dispatcher mirroring the router's current observable state."""
    d = Dispatcher(router.policy)
    for rid in router._order:
        rep = router.replicas[rid]
        d.executor_joined(rid, now=0.0, slots=rep.slots)
        d.executors[rid].busy = rep.busy
    d.sizes.update(router.sizes)
    for oid in router.sizes:
        for rid in router.index.lookup(oid):
            d.index.insert(oid, rid)
    return d


def dispatcher_prediction(router: PrefixAwareRouter,
                          prompt: Sequence[int]) -> dict:
    """What the core stack says the router must do with ``prompt``."""
    d = dispatcher_twin(router)
    oids = prefix_chain(prompt, router.block)
    for oid in oids:
        d.sizes.setdefault(oid, router.page_bytes)
    probe = Task(inputs=tuple(oids))
    d.submit([probe], now=0.0)
    # brute-force scores for the queued probe (satellite lock target);
    # the incremental maps must already match them at this quiescent point
    ref = d.reference_scores()
    scores = {rid: ref.get(rid, {}).get(probe.tid, 0) for rid in router._order}
    avail = [r for r in router._order if d.executors[r].available]
    busy = [r for r in router._order if not d.executors[r].available]
    dec = decide(router.policy, probe, avail, busy, d.index, d.sizes)
    return {
        "target": dec.executor or dec.wait_for,   # None == unplaceable
        "scores": scores,
        # the incremental _exec_scores maps exist only under MCU; for the
        # fifo policies the brute force is the only scoring there is
        "incremental_consistent": (d.scores_match_reference()
                                   if d._mcu else True),
    }


def verify_route(router: PrefixAwareRouter, prompt: Sequence[int]) -> dict:
    """Predict, then actually route; report both agreements.  Mutates the
    router exactly like a normal ``route()`` call (the caller completes)."""
    pred = dispatcher_prediction(router, prompt)
    router_scores = router.reference_scores(prompt)
    res: RouteResult = router.route(prompt)
    return {
        "prediction": pred,
        "routed": res.replica,
        "route_result": res,
        # target None == every path saturated; the router's least-busy
        # fallback is then serving policy, not core-stack disagreement
        "placement_agrees": (pred["target"] is None
                             or res.replica == pred["target"]),
        "scores_agree": router_scores == pred["scores"],
    }

"""Serving driven through the real scheduling stack (DESIGN.md §12).

The third experiment engine: ``ExperimentSpec(engine="serve")`` runs a
multi-turn session workload (``WorkloadSpec.sessions``) end-to-end through
the UNCHANGED Dispatcher + DRP + obs machinery -- replica == executor,
request == task, cached-prefix-KV bytes == the overlap score -- and emits
the same 35-field RunReport as sim/runtime.

  binding     replica==executor mapping table + serve-legality checks +
              the `session_spec` convenience constructor
  engine      ServeDiffusionEngine (RuntimeEngine subclass, name="serve")
  kvmetrics   RunReport -> KV-reuse economics (reused vs recomputed bytes,
              pool trajectory formatting)
  reference   regression lock: a Dispatcher twin predicts what the
              PrefixAwareRouter must choose

Import note: this package is resolved lazily by the experiment layer
(``LAZY_ENGINES``) because `engine` imports `repro.experiments`, which
imports `repro.workloads`, which imports `repro.serve.kvcache` -- eager
registration would be a cycle.
"""
from .binding import SERVE_MAPPING, check_serve_spec, session_spec
from .engine import ServeDiffusionEngine
from .kvmetrics import format_pool, kv_summary, pool_trajectory
from .reference import dispatcher_prediction, verify_route

__all__ = [
    "SERVE_MAPPING",
    "ServeDiffusionEngine",
    "check_serve_spec",
    "dispatcher_prediction",
    "format_pool",
    "kv_summary",
    "pool_trajectory",
    "session_spec",
    "verify_route",
]

"""Replica==executor mapping + which spec knobs are serve-legal.

``SERVE_MAPPING`` is the DESIGN.md §12 table in data form (a test renders
it, so docs and code cannot drift): every serving concept and the existing
diffusion mechanism that implements it VERBATIM -- the point of the
subsystem is that nothing in `repro.core` changed to make serving work.

``check_serve_spec`` is the PR-4 dead-knob rule applied to the serve
engine: a knob the engine would silently ignore hard-errors instead.
"""
from __future__ import annotations

from repro.experiments.engines import _reject
from repro.experiments.spec import (CacheSpec, ClusterSpec, ExperimentSpec,
                                    ObserveSpec, ProvisionerSpec,
                                    WorkloadSpec)

#: (serving concept, diffusion mechanism, where it lives) -- rendered into
#: DESIGN.md §12 and locked by tests/test_serve_diffusion.py
SERVE_MAPPING: tuple[tuple[str, str, str], ...] = (
    ("model replica",
     "executor (1-slot worker thread)",
     "repro.core.runtime.DiffusionRuntime"),
    ("inference request (one turn)",
     "Task with k prefix-page inputs (a correlated join)",
     "repro.workloads.sessions.SessionModel"),
    ("prefix-KV page (block tokens)",
     "immutable content-addressed DataObject of block*kv_bpt bytes",
     "repro.serve.kvcache.prefix_chain"),
    ("prefix-aware load balancing",
     "max-compute-util dispatch: cached-prefix bytes == overlap score",
     "repro.core.scheduler._dispatch_mcu"),
    ("KV transfer from a peer replica",
     "peer cache fetch (bytes_c2c / peer_hits in the ledger)",
     "repro.core.runtime peer fetch accounting"),
    ("prefill recompute (cache miss)",
     "store read (bytes_store / store_reads in the ledger)",
     "repro.core.runtime.ObjectStore"),
    ("replica autoscaling under demand",
     "DynamicResourceProvisioner grow/shrink on queue + idle signals",
     "repro.core.provisioner via engines._ProvisionerDriver"),
    ("cluster-wide KV page directory",
     "LocationIndex (loose coherence via index_update_batch)",
     "repro.core.index"),
    ("request lifecycle telemetry",
     "obs lifecycle events -> Chrome trace / sim divergence diff",
     "repro.obs (DESIGN.md §10)"),
)


def check_serve_spec(spec: ExperimentSpec) -> None:
    """Serve-legality: the serve engine is the threaded runtime with
    serving semantics, so it inherits every runtime reject (cpus_per_node,
    write_outputs_to, ...) from RuntimeEngine.prepare and adds its own."""
    if spec.hosts != 0:
        _reject("serve", "hosts", spec.hosts,
                "0 (replicas are in-process worker threads; fleet-mode "
                "serving is the runtime engine's business)")
    if spec.workload.dag is not None:
        raise ValueError(
            "serve engine: workload.dag is not serve-legal -- serving "
            "requests are dep-free joins over prefix pages (bind "
            "workload.sessions, a trace, or a flat generator instead)")


def session_spec(name: str = "serve",
                 sessions: dict | None = None,
                 *,
                 n_replicas: int = 4,
                 policy: str = "max-compute-util",
                 replica_cache_bytes: int = 1 << 30,
                 provisioner: ProvisionerSpec | None = None,
                 observe: ObserveSpec | None = None,
                 seed: int = 0,
                 **spec_kw) -> ExperimentSpec:
    """An ExperimentSpec shaped for the serve engine: a sessions binding
    on an n_replicas single-slot pool.  One construction path shared by
    the example, the benches and the tests."""
    return ExperimentSpec(
        name=name,
        workload=WorkloadSpec(
            name=name,
            sessions=dict(sessions) if sessions else {"kind": "chat"}),
        cluster=ClusterSpec(n_nodes=n_replicas),
        cache=CacheSpec(capacity_bytes=replica_cache_bytes),
        policy=policy,
        provisioner=provisioner,
        observe=observe if observe is not None else ObserveSpec(),
        seed=seed,
        **spec_kw)

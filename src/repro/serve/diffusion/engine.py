"""ServeDiffusionEngine: serving as a third Engine-protocol adapter.

A deliberate near-alias of RuntimeEngine: the WHOLE claim of DESIGN.md §12
is that serving needs no new scheduling machinery -- replica == executor,
request == task, KV page == cached object -- so the adapter contributes
exactly (a) the serve-legality checks and (b) the name.  Everything else
(`_dispatch_mcu` scoring over prefix pages, ShardedIndex/LocationIndex
coherence, peer KV fetch accounting, DRP replica autoscaling, obs
lifecycle events, the 35-field report) is the inherited runtime path,
executing a `WorkloadSpec.sessions` workload.  ``build_report`` tags the
result with ``self.name``, so reports come out ``engine="serve"`` and
``RunReport.diff`` against sim/runtime reports works field-by-field.

Per-input accounting IS the KV ledger: a local hit = the replica already
holds the prefix page, a peer hit = KV fetched from another replica
(bytes_c2c), a store read = prefill recompute (bytes_store).  kvmetrics
turns one report into the serving headline numbers.
"""
from __future__ import annotations

from typing import Optional

from repro.experiments.engines import RuntimeEngine
from repro.experiments.spec import ExperimentSpec
from repro.workloads import Workload

from .binding import check_serve_spec


class ServeDiffusionEngine(RuntimeEngine):
    """`make_engine("serve")` -- registered via LAZY_ENGINES."""

    name = "serve"

    def prepare(self, spec: ExperimentSpec,
                workload: Optional[Workload] = None
                ) -> "ServeDiffusionEngine":
        check_serve_spec(spec)
        super().prepare(spec, workload)
        return self

    def _engine_gauges(self) -> None:
        """Telemetry hook (DESIGN.md §13): the KV-reuse byte split.  Reused
        KV = prefix pages served from cache (local or peer); prefill =
        bytes recomputed from the store.  Same ledger the report's
        kvmetrics read, sampled live."""
        m = self.runtime.metrics
        if m is None:
            return
        led = self.runtime.ledger
        with led.lock:
            reused = led.bytes_local + led.bytes_c2c
            prefill = led.bytes_store
        m.gauge_set("serve.kv_reused_bytes", reused)
        m.gauge_set("serve.kv_prefill_bytes", prefill)

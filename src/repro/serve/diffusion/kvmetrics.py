"""KV-reuse economics out of a RunReport.

The report's per-input ledger already IS the KV ledger (engine.py's
mapping); this module only renames and ratios it.  Because every session
page is uniformly sized (``block * kv_bytes_per_token`` -- see
repro.workloads.sessions), the reused-BYTES fraction equals the
reused-TOKEN fraction exactly, which is why `examples/serve_sessions.py`
can print "reused token fraction" straight from byte counters.
"""
from __future__ import annotations


def kv_summary(report) -> dict:
    """Serving headline numbers from any engine's RunReport (sim twin
    reports work too -- the ledger fields are schema-shared)."""
    b = report.bytes_by_kind
    local = float(b.get("local", 0.0))
    peer = float(b.get("c2c", 0.0))
    recomputed = float(b.get("store_read", 0.0))
    reused = local + peer
    total = reused + recomputed
    return {
        "reused_kv_bytes": reused,
        "local_kv_bytes": local,
        "peer_kv_bytes": peer,
        "recomputed_kv_bytes": recomputed,
        # uniform pages => byte fraction == token fraction
        "reused_token_fraction": reused / total if total else 0.0,
        "full_reuse_requests": report.full_hit_tasks,
        "partial_reuse_requests": report.partial_hit_tasks,
        "cold_requests": report.zero_hit_tasks,
        "n_requests": report.n_completed,
    }


def pool_trajectory(report, max_points: int = 16) -> list[tuple[float, int]]:
    """Replica-pool (t, live) samples, evenly thinned to ``max_points``
    (first and last always kept) -- the DRP grow/shrink story in one line."""
    log = [(float(t), int(n)) for t, n in report.pool_log]
    if len(log) <= max_points:
        return log
    step = (len(log) - 1) / (max_points - 1)
    idx = sorted({round(i * step) for i in range(max_points)})
    return [log[i] for i in idx]


def format_pool(report, max_points: int = 16) -> str:
    """Deterministic one-line rendering: ``t:live`` pairs, 1 decimal."""
    pts = pool_trajectory(report, max_points)
    if not pts:
        return "(fixed pool)"
    return " ".join(f"{t:.1f}s:{n}" for t, n in pts)

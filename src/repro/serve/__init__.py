from .engine import Request, ServeEngine
from .kvcache import kv_bytes_per_token, prefix_chain, prefix_oid
from .router import PrefixAwareRouter, RouteResult

__all__ = ["PrefixAwareRouter", "Request", "RouteResult", "ServeEngine",
           "kv_bytes_per_token", "prefix_chain", "prefix_oid"]

"""Prefix-KV serving on the diffusion stack.

Layering note: ``kvcache`` and ``router`` are pure-Python (hashing + the
core cache/index/policy machinery) and import eagerly -- the workload
layer's session generator builds prefix-chain oids through them without
touching an accelerator.  ``ServeEngine`` / ``Request`` pull in jax and the
model substrate, so they resolve lazily on first attribute access; the
``diffusion`` subpackage (the Engine-protocol adapter) likewise resolves
lazily because it imports ``repro.experiments``, which imports
``repro.workloads``, which imports this package's ``kvcache``.
"""
from .kvcache import kv_bytes_per_token, prefix_chain, prefix_oid
from .router import PrefixAwareRouter, RouteResult

__all__ = ["PrefixAwareRouter", "Request", "RouteResult", "ServeEngine",
           "kv_bytes_per_token", "prefix_chain", "prefix_oid"]

#: lazily resolved attribute -> defining submodule
_LAZY = {"Request": "engine", "ServeEngine": "engine"}


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        mod = importlib.import_module(f".{_LAZY[name]}", __name__)
        value = getattr(mod, name)
        globals()[name] = value   # cache: __getattr__ runs once per name
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

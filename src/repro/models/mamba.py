"""Mamba-1 selective SSM block (falcon-mamba / jamba mamba layers).

Train/prefill uses an associative scan over the sequence (log-depth on TPU);
decode is the O(1) recurrent update.  A chunked Pallas kernel for the scan
lives in repro.kernels.mamba_scan; this module is the reference/pure-JAX
path and the shape/param owner.

Shapes (per layer): d_inner = expand * d_model, N = d_state, R = dt_rank.
  in_proj  (D, 2*d_inner)     conv1d  (K, d_inner)      x_proj (d_inner, R+2N)
  dt_proj  (R, d_inner)       A_log   (d_inner, N)      D      (d_inner,)
  out_proj (d_inner, D)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import LogicalRules, shard


def mamba_param_shapes(d_model: int, d_inner: int, d_state: int,
                       d_conv: int, dt_rank: int) -> dict:
    return {
        "in_proj": ((d_model, 2 * d_inner), ("fsdp", "tp")),
        "conv_w": ((d_conv, d_inner), (None, "tp_fsdp")),
        "conv_b": ((d_inner,), ("tp_fsdp",)),
        "x_proj": ((d_inner, dt_rank + 2 * d_state), ("tp_fsdp", None)),
        "dt_proj": ((dt_rank, d_inner), (None, "tp_fsdp")),
        "dt_bias": ((d_inner,), ("tp_fsdp",)),
        "A_log": ((d_inner, d_state), ("tp_fsdp", None)),
        "D": ((d_inner,), ("tp_fsdp",)),
        "out_proj": ((d_inner, d_model), ("tp", "fsdp")),
    }


def _ssm_scan(u: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
              Cm: jax.Array, D: jax.Array,
              h0: Optional[jax.Array] = None) -> tuple[jax.Array, jax.Array]:
    """Selective scan.  u,dt: (B,S,I); A: (I,N); Bm,Cm: (B,S,N); D: (I,).
    Returns (y (B,S,I), h_last (B,I,N)).

    Sequential lax.scan over time: an associative_scan here keeps O(log S)
    levels of (B,S,I,N) fp32 tensors live through the BACKWARD pass
    (~4.3 GB/chunk measured at falcon train_4k); the sequential form saves
    only the (B,I,N) carry per step.  The time recursion is elementwise
    (I*N flops/step, negligible vs the projections); on real TPUs the
    Pallas kernel (use_mamba_kernel) replaces this path anyway."""
    B, S, I = u.shape
    if h0 is None:
        h0 = jnp.zeros((B, I, A.shape[1]), jnp.float32)
    dA = jnp.exp(dt[..., None] * A[None, None])                  # (B,S,I,N)
    dBu = dt[..., None] * Bm[:, :, None, :] * u[..., None]       # (B,S,I,N)

    def step(h, xs):
        dA_t, dBu_t = xs
        h = dA_t * h + dBu_t
        return h, h

    h_last, hs = jax.lax.scan(step, h0, (jnp.moveaxis(dA, 1, 0),
                                         jnp.moveaxis(dBu, 1, 0)))
    hs = jnp.moveaxis(hs, 0, 1)                                  # (B,S,I,N)
    y = jnp.einsum("bsin,bsn->bsi", hs, Cm) + u * D[None, None]
    return y, h_last


def mamba_block(
    x: jax.Array,                 # (B, S, D)
    p: dict,
    rules: Optional[LogicalRules] = None,
    conv_state: Optional[jax.Array] = None,   # (B, K-1, I) carried context
    ssm_state: Optional[jax.Array] = None,    # (B, I, N)
    return_state: bool = False,
    use_kernel: bool = False,
    chunk: int = 256,
):
    """Full-sequence Mamba block (train / prefill)."""
    B, S, D = x.shape
    K, I = p["conv_w"].shape
    N = p["A_log"].shape[-1]
    R = p["dt_proj"].shape[0]

    xz = jnp.einsum("bsd,di->bsi", x, p["in_proj"].astype(x.dtype))
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = shard(xs, rules, "batch", None, "tp")

    # causal depthwise conv1d as K shifted multiply-adds (K static, small):
    # the windows/einsum (im2col) form materializes (I, K, S) fp32 tensors
    # in its backward -- ~3.5 GB/dev at falcon train_4k, measured.
    pad = conv_state if conv_state is not None else jnp.zeros(
        (B, K - 1, I), dtype=xs.dtype)
    xpad = jnp.concatenate([pad, xs], axis=1)                    # (B,S+K-1,I)
    w = p["conv_w"].astype(x.dtype)
    xc = sum(xpad[:, k: k + S] * w[k][None, None, :] for k in range(K))
    xc = xc + p["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(xc)
    new_conv_state = xpad[:, S:] if K > 1 else pad

    proj = jnp.einsum("bsi,ir->bsr", xc, p["x_proj"].astype(x.dtype))
    dt_r, Bm, Cm = jnp.split(proj.astype(jnp.float32), [R, R + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,ri->bsi", dt_r,
                                    p["dt_proj"].astype(jnp.float32))
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if use_kernel:
        from repro.kernels.mamba_scan import ops as ms_ops
        y, h_last = ms_ops.mamba_scan(xc.astype(jnp.float32), dt, A, Bm, Cm,
                                      p["D"].astype(jnp.float32),
                                      h0=ssm_state)
    else:
        # chunked over the sequence: one un-chunked associative scan
        # materializes (B, S, I, N) fp32 intermediates -- 4.3 GB/device/
        # tensor at jamba train_4k (measured).  A static python loop keeps
        # the live set to one chunk and keeps HLO flop counting honest.
        Dv = p["D"].astype(jnp.float32)
        u32 = xc.astype(jnp.float32)
        h = ssm_state                     # None => zero initial state
        ys = []
        step = min(chunk, S) if chunk > 0 else S
        scan_ck = jax.checkpoint(_ssm_scan)   # bwd holds one chunk, not all
        for s0 in range(0, S, step):
            sl = slice(s0, min(s0 + step, S))
            y_c, h = scan_ck(u32[:, sl], dt[:, sl], A, Bm[:, sl],
                             Cm[:, sl], Dv, h0=h)
            ys.append(y_c)
        y = ys[0] if len(ys) == 1 else jnp.concatenate(ys, axis=1)
        h_last = h
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x.dtype))
    out = shard(out, rules, "batch", None, None)
    if return_state:
        return out, new_conv_state, h_last
    return out


def mamba_decode(
    x: jax.Array,                  # (B, 1, D)
    p: dict,
    conv_state: jax.Array,         # (B, K-1, I)
    ssm_state: jax.Array,          # (B, I, N) fp32
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """O(1) single-token recurrence (long_500k decode path)."""
    B, _, D = x.shape
    K, I = p["conv_w"].shape
    N = p["A_log"].shape[-1]
    R = p["dt_proj"].shape[0]

    xz = jnp.einsum("bsd,di->bsi", x, p["in_proj"].astype(x.dtype))
    xs, z = jnp.split(xz, 2, axis=-1)                             # (B,1,I)
    window = jnp.concatenate([conv_state, xs], axis=1)            # (B,K,I)
    xc = jnp.einsum("bki,ki->bi", window, p["conv_w"].astype(x.dtype))
    xc = jax.nn.silu(xc + p["conv_b"].astype(x.dtype))            # (B,I)
    new_conv_state = window[:, 1:]

    proj = jnp.einsum("bi,ir->br", xc, p["x_proj"].astype(x.dtype))
    dt_r, Bm, Cm = jnp.split(proj.astype(jnp.float32), [R, R + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("br,ri->bi", dt_r,
                                    p["dt_proj"].astype(jnp.float32))
                         + p["dt_bias"].astype(jnp.float32))      # (B,I)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (I,N)
    dA = jnp.exp(dt[..., None] * A[None])                         # (B,I,N)
    dBu = dt[..., None] * Bm[:, None, :] * xc.astype(jnp.float32)[..., None]
    h = dA * ssm_state + dBu                                      # (B,I,N)
    y = jnp.einsum("bin,bn->bi", h, Cm) + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)[None]
    y = (y.astype(x.dtype) * jax.nn.silu(z[:, 0]))[:, None, :]    # (B,1,I)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x.dtype))
    return out, new_conv_state, h

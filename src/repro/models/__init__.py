from .config import LayerSpec, ModelConfig
from .model import (abstract_cache, batch_logical, input_specs, lm_loss,
                    make_forward, make_loss_fn, make_prefill, make_serve_step,
                    make_train_step)
from .transformer import (abstract_params, cache_logical, init_cache,
                          init_params, param_defs, param_logical)

__all__ = [
    "LayerSpec",
    "ModelConfig",
    "abstract_cache",
    "abstract_params",
    "batch_logical",
    "cache_logical",
    "init_cache",
    "init_params",
    "input_specs",
    "lm_loss",
    "make_forward",
    "make_loss_fn",
    "make_prefill",
    "make_serve_step",
    "make_train_step",
    "param_defs",
    "param_logical",
]

"""Patterned decoder (all LM-family archs) + encoder-decoder (whisper).

Parameters are explicit nested dicts; per-block params are stacked with a
leading n_blocks dim and consumed by lax.scan, so the traced program is one
block long regardless of depth (essential for the 1-core dry-run compiles).
Each leaf has a parallel *logical axis* tuple used by repro.parallel.sharding
to derive pjit shardings.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import LogicalRules, shard, shard_tree
from . import layers as L
from .config import LayerSpec, ModelConfig
from .mamba import mamba_block, mamba_decode, mamba_param_shapes
from .moe import moe_block_sharded, moe_param_shapes


class ParamDef(NamedTuple):
    shape: tuple[int, ...]
    logical: tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones
    dtype: str = "param"      # param (cfg.dtype) | float32


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def _attn_defs(cfg: ModelConfig) -> dict[str, ParamDef]:
    D, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    return {
        "wq": ParamDef((D, H, dh), ("fsdp", "tp", None)),
        "wk": ParamDef((D, KV, dh), ("fsdp", "tp", None)),
        "wv": ParamDef((D, KV, dh), ("fsdp", "tp", None)),
        "wo": ParamDef((H, dh, D), ("tp", None, "fsdp")),
    }


def _mlp_defs(cfg: ModelConfig) -> dict[str, ParamDef]:
    D, F = cfg.d_model, cfg.d_ff
    defs = {
        "w_up": ParamDef((D, F), ("fsdp", "tp")),
        "w_down": ParamDef((F, D), ("tp", "fsdp")),
    }
    if cfg.gated_mlp:
        defs["w_gate"] = ParamDef((D, F), ("fsdp", "tp"))
    return defs


def _norm_defs(cfg: ModelConfig, name: str) -> dict[str, ParamDef]:
    D = cfg.d_model
    if cfg.norm == "layer":
        return {f"{name}_scale": ParamDef((D,), (None,), "ones", "float32"),
                f"{name}_bias": ParamDef((D,), (None,), "zeros", "float32")}
    init = "zeros" if cfg.rms_plus_one else "ones"
    return {f"{name}_scale": ParamDef((D,), (None,), init, "float32")}


def _sub_defs(cfg: ModelConfig, spec: LayerSpec) -> dict[str, Any]:
    defs: dict[str, Any] = {}
    defs.update(_norm_defs(cfg, "ln1"))
    if spec.kind == "attn":
        defs.update(_attn_defs(cfg))
    else:
        for k, (shape, logical) in mamba_param_shapes(
                cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv,
                cfg.dt_rank).items():
            dt = "float32" if k in ("A_log", "D", "dt_bias", "conv_b") else "param"
            defs[k] = ParamDef(shape, logical, "normal" if k not in
                               ("dt_bias", "conv_b", "D") else "zeros", dt)
    if cfg.post_norms:
        defs.update(_norm_defs(cfg, "post_ln1"))
    if spec.mlp == "dense":
        defs.update(_norm_defs(cfg, "ln2"))
        defs.update(_mlp_defs(cfg))
    elif spec.mlp == "moe":
        defs.update(_norm_defs(cfg, "ln2"))
        for k, (shape, logical) in moe_param_shapes(
                cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.gated_mlp).items():
            defs[k] = ParamDef(shape, logical,
                               dtype="float32" if k == "w_router" else "param")
    if cfg.post_norms and spec.mlp != "none":
        defs.update(_norm_defs(cfg, "post_ln2"))
    return defs


def _stack(defs: dict[str, ParamDef], n: int) -> dict[str, ParamDef]:
    return {k: ParamDef((n,) + d.shape, ("layers",) + d.logical, d.init, d.dtype)
            for k, d in defs.items()}


def param_defs(cfg: ModelConfig) -> dict[str, Any]:
    V, D = cfg.vocab_size, cfg.d_model
    defs: dict[str, Any] = {
        "embed": ParamDef((V, D), ("tp", "fsdp")),
        "blocks": {f"sub{i}": _stack(_sub_defs(cfg, spec), cfg.n_blocks)
                   for i, spec in enumerate(cfg.pattern)},
    }
    defs.update(_norm_defs(cfg, "final"))
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((V, D), ("tp", "fsdp"))
    if not cfg.use_rope and cfg.max_learned_pos > 0:
        defs["pos_embed"] = ParamDef((cfg.max_learned_pos, D), (None, "fsdp"))
    if cfg.is_encdec:
        enc_sub = {}
        enc_sub.update(_norm_defs(cfg, "ln1"))
        enc_sub.update(_attn_defs(cfg))
        enc_sub.update(_norm_defs(cfg, "ln2"))
        enc_sub.update(_mlp_defs(cfg))
        defs["encoder"] = {"sub0": _stack(enc_sub, cfg.enc_layers)}
        defs.update({f"enc_{k}": v for k, v in _norm_defs(cfg, "final").items()})
        cross = {}
        cross.update(_norm_defs(cfg, "ln_x"))
        cross.update({f"x_{k}": v for k, v in _attn_defs(cfg).items()})
        defs["cross"] = {"sub0": _stack(cross, cfg.n_layers)}
    return defs


def _materialize(key: jax.Array, d: ParamDef, cfg: ModelConfig) -> jax.Array:
    dtype = jnp.float32 if d.dtype == "float32" else jnp.dtype(cfg.dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(dtype)


def init_params(cfg: ModelConfig, key: jax.Array):
    defs = param_defs(cfg)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    vals = [_materialize(k, d, cfg) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree -- used by the dry-run (no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(
            d.shape, jnp.float32 if d.dtype == "float32" else jnp.dtype(cfg.dtype)),
        param_defs(cfg), is_leaf=lambda x: isinstance(x, ParamDef))


def param_logical(cfg: ModelConfig):
    return jax.tree.map(lambda d: d.logical, param_defs(cfg),
                        is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _norm(cfg: ModelConfig, x, p, name):
    if cfg.norm == "layer":
        return L.layer_norm(x, p[f"{name}_scale"], p[f"{name}_bias"])
    return L.rms_norm(x, p[f"{name}_scale"], plus_one=cfg.rms_plus_one)


def _variant(cfg: ModelConfig, spec: LayerSpec, causal: bool = True) -> L.AttnVariant:
    return L.AttnVariant(kind=spec.attn, window=cfg.window,
                         softcap=cfg.attn_softcap, causal=causal)


def _apply_sub(cfg: ModelConfig, spec: LayerSpec, x, p, positions, rules,
               causal: bool = True):
    """One sub-layer (token-mixer + channel-mixer) with residuals.
    Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = _norm(cfg, x, p, "ln1")
    if spec.kind == "attn":
        h = L.attention_block(h, p, positions, _variant(cfg, spec, causal),
                              cfg.rope_theta, rules, use_rope=cfg.use_rope,
                              impl=cfg.attn_impl)
    else:
        h = mamba_block(h, p, rules, use_kernel=cfg.use_mamba_kernel,
                        chunk=cfg.ssm_chunk)
    if cfg.post_norms:
        h = _norm(cfg, h, p, "post_ln1")
    x = x + h
    if spec.mlp != "none":
        h = _norm(cfg, x, p, "ln2")
        if spec.mlp == "moe":
            h, aux = moe_block_sharded(h, p, cfg, rules)
        else:
            h = L.mlp_block(h, p, cfg.mlp_act, rules)
        if cfg.post_norms:
            h = _norm(cfg, h, p, "post_ln2")
        x = x + h
    return x, aux


@jax.custom_jvp
def _grad_safe_barrier(x: jax.Array) -> jax.Array:
    """``optimization_barrier`` with a differentiation rule.

    ``jax.lax.optimization_barrier`` has no JVP/VJP registered, so any grad
    taken through the remat'd block scan dies with NotImplementedError.  The
    barrier only needs to pin the *primal* against convert-hoisting; the
    tangent passes through untouched (identity), which also gives reverse
    mode a well-defined (identity) transpose.
    """
    return jax.lax.optimization_barrier(x)


@_grad_safe_barrier.defjvp
def _grad_safe_barrier_jvp(primals, tangents):
    (x,), (dx,) = primals, tangents
    return _grad_safe_barrier(x), dx


def _block_fn(cfg: ModelConfig, rules, positions, causal=True):
    def fn(x, block_params):
        # barrier INSIDE the checkpointed fn: stops convert-hoisting of the
        # saved carry stack in the backward pass as well as the forward
        x = _grad_safe_barrier(x)
        aux_total = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(cfg.pattern):
            x, aux = _apply_sub(cfg, spec, x, block_params[f"sub{i}"],
                                positions, rules, causal)
            aux_total = aux_total + aux
        # sequence-parallel residual: the scan carry (what bwd must save)
        # is sharded over the model axis along seq (rules: "act_seq")
        x = shard(x, rules, "batch", "act_seq", None)
        return x, aux_total
    return fn


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def _block_logical(cfg: ModelConfig, sub_defs: dict) -> dict:
    """Per-block logical axes (the stacked "layers" dim stripped)."""
    return jax.tree.map(lambda d: d.logical[1:], sub_defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def _scan_blocks(cfg: ModelConfig, x, blocks, rules, positions, causal=True):
    fn = _remat(cfg, _block_fn(cfg, rules, positions, causal))
    blocks_lg = {f"sub{i}": _block_logical(cfg, _stack(_sub_defs(cfg, spec),
                                                       cfg.n_blocks))
                 for i, spec in enumerate(cfg.pattern)}

    def step(carry, block_params):
        # pin per-layer param sharding inside the loop (ZeRO-3 gather point;
        # the transpose of this constraint shards the grad stacks)
        block_params = shard_tree(block_params, rules, blocks_lg)
        y, aux = fn(carry, block_params)
        return y, aux

    x, auxs = jax.lax.scan(step, x, blocks, unroll=cfg.scan_unroll)
    return x, jnp.sum(auxs)


def embed_inputs(cfg: ModelConfig, params, batch: dict,
                 rules: Optional[LogicalRules] = None) -> jax.Array:
    """tokens (+ stub frontend embeddings) -> (B, S, D) residual stream."""
    if "inputs_embeds" in batch:
        x = batch["inputs_embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = L.embed(batch["tokens"], params["embed"], rules, cfg.embed_scale)
        if "image_embeds" in batch:
            x = jax.lax.dynamic_update_slice(
                x, batch["image_embeds"].astype(x.dtype),
                (0, cfg.frontend_offset, 0))
    if "pos_embed" in params:
        x = x + params["pos_embed"][: x.shape[1]][None].astype(x.dtype)
    return x


def forward_lm(
    cfg: ModelConfig,
    params,
    tokens: jax.Array,                      # (B, S) int32
    rules: Optional[LogicalRules] = None,
    image_embeds: Optional[jax.Array] = None,   # (B, T_img, D) vlm stub
    inputs_embeds: Optional[jax.Array] = None,  # (B, S, D) audio-enc stub
) -> tuple[jax.Array, jax.Array]:
    """Returns (logits (B,S,V) fp32, moe_aux scalar)."""
    batch = {"tokens": tokens}
    if image_embeds is not None:
        batch["image_embeds"] = image_embeds
    if inputs_embeds is not None:
        batch["inputs_embeds"] = inputs_embeds
    x = embed_inputs(cfg, params, batch, rules)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x, aux = _scan_blocks(cfg, x, params["blocks"], rules, positions)
    x = _norm(cfg, x, params, "final")
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed(x, table, cfg.final_softcap, rules)
    return logits, aux


def forward_lm_hidden(cfg: ModelConfig, params, batch: dict,
                      rules: Optional[LogicalRules] = None
                      ) -> tuple[jax.Array, jax.Array]:
    """Forward up to the final norm (no unembed) -- the chunked-loss path."""
    x = embed_inputs(cfg, params, batch, rules)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, aux = _scan_blocks(cfg, x, params["blocks"], rules, positions)
    return _norm(cfg, x, params, "final"), aux


# ---------------------------------------------------------------------------
# Encoder-decoder (whisper): frontend is a stub -- encoder consumes
# precomputed frame embeddings from input_specs().
# ---------------------------------------------------------------------------

def forward_encdec(
    cfg: ModelConfig,
    params,
    frame_embeds: jax.Array,               # (B, S_enc, D)
    dec_tokens: jax.Array,                 # (B, S_dec)
    rules: Optional[LogicalRules] = None,
) -> tuple[jax.Array, jax.Array]:
    enc = encode(cfg, params, frame_embeds, rules)
    return decode_train(cfg, params, enc, dec_tokens, rules)


def encode(cfg: ModelConfig, params, frame_embeds, rules=None) -> jax.Array:
    x = frame_embeds.astype(jnp.dtype(cfg.dtype))
    S = x.shape[1]
    pos = _sinusoid(S, cfg.d_model).astype(x.dtype)
    x = x + pos[None]
    positions = jnp.arange(S, dtype=jnp.int32)
    x, _ = _scan_blocks(cfg.with_(pattern=(LayerSpec(kind="attn", attn="full",
                                                     mlp="dense"),),
                                  n_layers=cfg.enc_layers),
                        x, params["encoder"], rules, positions, causal=False)
    return _norm(cfg, x, params, "enc_final")


def decode_train(cfg: ModelConfig, params, enc, dec_tokens, rules=None):
    x = L.embed(dec_tokens, params["embed"], rules, cfg.embed_scale)
    S = x.shape[1]
    if "pos_embed" in params:
        x = x + params["pos_embed"][:S][None].astype(x.dtype)
    positions = jnp.arange(S, dtype=jnp.int32)

    def block(carry, ps):
        # whisper ordering: self-attn -> cross-attn -> mlp
        self_p, cross_p = ps
        x = carry
        h = _norm(cfg, x, self_p, "ln1")
        h = L.attention_block(h, self_p, positions,
                              _variant(cfg, cfg.pattern[0]), cfg.rope_theta,
                              rules, use_rope=cfg.use_rope,
                              impl=cfg.attn_impl)
        x = x + h
        hx = _norm(cfg, x, cross_p, "ln_x")
        xp = {k[2:]: v for k, v in cross_p.items() if k.startswith("x_")}
        x = x + L.cross_attention_block(hx, enc, xp, rules)
        h = _norm(cfg, x, self_p, "ln2")
        x = x + L.mlp_block(h, self_p, cfg.mlp_act, rules)
        return x, jnp.zeros((), jnp.float32)

    blocks = (params["blocks"]["sub0"], params["cross"]["sub0"])
    x, auxs = jax.lax.scan(_remat(cfg, block), x, blocks,
                           unroll=cfg.scan_unroll)
    x = _norm(cfg, x, params, "final")
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.unembed(x, table, cfg.final_softcap, rules), jnp.sum(auxs)


def forward_encdec_hidden(cfg: ModelConfig, params, frame_embeds, dec_tokens,
                          rules: Optional[LogicalRules] = None):
    """Enc-dec forward up to the decoder's final norm (chunked-loss path).
    Mirrors decode_train but stops before unembed."""
    enc = encode(cfg, params, frame_embeds, rules)
    x = L.embed(dec_tokens, params["embed"], rules, cfg.embed_scale)
    S = x.shape[1]
    if "pos_embed" in params:
        x = x + params["pos_embed"][:S][None].astype(x.dtype)
    positions = jnp.arange(S, dtype=jnp.int32)

    def block(carry, ps):
        self_p, cross_p = ps
        x = carry
        h = _norm(cfg, x, self_p, "ln1")
        h = L.attention_block(h, self_p, positions,
                              _variant(cfg, cfg.pattern[0]), cfg.rope_theta,
                              rules, use_rope=cfg.use_rope,
                              impl=cfg.attn_impl)
        x = x + h
        hx = _norm(cfg, x, cross_p, "ln_x")
        xp = {k[2:]: v for k, v in cross_p.items() if k.startswith("x_")}
        x = x + L.cross_attention_block(hx, enc, xp, rules)
        h = _norm(cfg, x, self_p, "ln2")
        x = x + L.mlp_block(h, self_p, cfg.mlp_act, rules)
        return x, jnp.zeros((), jnp.float32)

    blocks = (params["blocks"]["sub0"], params["cross"]["sub0"])
    x, auxs = jax.lax.scan(_remat(cfg, block), x, blocks,
                           unroll=cfg.scan_unroll)
    return _norm(cfg, x, params, "final"), jnp.sum(auxs)


def _sinusoid(S: int, D: int) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(D // 2, dtype=jnp.float32)[None]
    angle = pos / jnp.power(10_000.0, 2 * dim / D)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# KV / state caches + single-token decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype: Optional[str] = None):
    """Abstract-friendly cache pytree. Leading dim of every leaf: n_blocks."""
    dt = jnp.dtype(dtype or cfg.dtype)
    nb, KV, dh = cfg.n_blocks, cfg.n_kv_heads, cfg.head_dim_
    cache: dict[str, Any] = {}
    for i, spec in enumerate(cfg.pattern):
        if spec.kind == "attn":
            Sc = cfg.kv_cache_len(spec, seq_len)
            cache[f"sub{i}"] = {
                "k": jnp.zeros((nb, batch, Sc, KV, dh), dt),
                "v": jnp.zeros((nb, batch, Sc, KV, dh), dt),
            }
        else:
            I, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
            cache[f"sub{i}"] = {
                "conv": jnp.zeros((nb, batch, K - 1, I), dt),
                "ssm": jnp.zeros((nb, batch, I, N), jnp.float32),
            }
    return cache


def cache_logical(cfg: ModelConfig):
    """Logical axes tree matching init_cache output."""
    out: dict[str, Any] = {}
    for i, spec in enumerate(cfg.pattern):
        if spec.kind == "attn":
            out[f"sub{i}"] = {"k": (None, "batch", "kv_seq", None, None),
                              "v": (None, "batch", "kv_seq", None, None)}
        else:
            out[f"sub{i}"] = {"conv": (None, "batch", None, "tp"),
                              "ssm": (None, "batch", "tp", None)}
    return out


def decode_step_lm(
    cfg: ModelConfig,
    params,
    cache,
    token: jax.Array,        # (B, 1) int32
    pos: jax.Array,          # scalar int32 -- absolute position
    rules: Optional[LogicalRules] = None,
) -> tuple[jax.Array, Any]:
    """One-token serve step: returns (logits (B,1,V), new cache)."""
    x = L.embed(token, params["embed"], rules, cfg.embed_scale)
    if "pos_embed" in params:
        x = x + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], pos, 1, axis=0)[None].astype(x.dtype)

    def block(carry, scanned):
        block_params, block_cache = scanned
        x = carry
        new_cache = {}
        for i, spec in enumerate(cfg.pattern):
            p = block_params[f"sub{i}"]
            c = block_cache[f"sub{i}"]
            h = _norm(cfg, x, p, "ln1")
            if spec.kind == "attn":
                h, ck, cv = L.attention_decode(
                    h, p, c["k"], c["v"], pos, _variant(cfg, spec),
                    cfg.rope_theta, use_rope=cfg.use_rope)
                new_cache[f"sub{i}"] = {"k": ck, "v": cv}
            else:
                h, conv, ssm = mamba_decode(h, p, c["conv"], c["ssm"])
                new_cache[f"sub{i}"] = {"conv": conv, "ssm": ssm}
            if cfg.post_norms:
                h = _norm(cfg, h, p, "post_ln1")
            x = x + h
            if spec.mlp != "none":
                h = _norm(cfg, x, p, "ln2")
                if spec.mlp == "moe":
                    h, _ = moe_block_sharded(h, p, cfg, rules)
                else:
                    h = L.mlp_block(h, p, cfg.mlp_act, rules)
                if cfg.post_norms:
                    h = _norm(cfg, h, p, "post_ln2")
                x = x + h
        return x, new_cache

    x, new_cache = jax.lax.scan(block, x, (params["blocks"], cache),
                                unroll=cfg.scan_unroll)
    x = _norm(cfg, x, params, "final")
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed(x, table, cfg.final_softcap, rules)
    return logits, new_cache


def decode_step_encdec(cfg: ModelConfig, params, cache, enc: jax.Array,
                       token: jax.Array, pos: jax.Array,
                       rules: Optional[LogicalRules] = None):
    """Whisper decode: self-attn cache + cross-attn against enc output."""
    x = L.embed(token, params["embed"], rules, cfg.embed_scale)
    if "pos_embed" in params:
        x = x + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], pos, 1, axis=0)[None].astype(x.dtype)

    def block(carry, scanned):
        (self_p, cross_p), block_cache = scanned
        x = carry
        c = block_cache["sub0"]
        h = _norm(cfg, x, self_p, "ln1")
        h, ck, cv = L.attention_decode(h, self_p, c["k"], c["v"], pos,
                                       _variant(cfg, cfg.pattern[0]),
                                       cfg.rope_theta, use_rope=cfg.use_rope)
        x = x + h
        hx = _norm(cfg, x, cross_p, "ln_x")
        xp = {k[2:]: v for k, v in cross_p.items() if k.startswith("x_")}
        x = x + L.cross_attention_block(hx, enc, xp, rules)
        h = _norm(cfg, x, self_p, "ln2")
        x = x + L.mlp_block(h, self_p, cfg.mlp_act, rules)
        return x, {"sub0": {"k": ck, "v": cv}}

    scanned = ((params["blocks"]["sub0"], params["cross"]["sub0"]), cache)
    x, new_cache = jax.lax.scan(block, x, scanned, unroll=cfg.scan_unroll)
    x = _norm(cfg, x, params, "final")
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.unembed(x, table, cfg.final_softcap, rules), new_cache

"""ModelConfig: one schema covering all ten assigned architectures.

A model is a repeated *pattern* of layer specs (period P), scanned over
n_layers/P blocks -- this expresses plain stacks (P=1), gemma2's local:global
alternation (P=2) and jamba's 1-attn:7-mamba interleave with alternating
MoE (P=8) with a single code path, and keeps the traced HLO one-block-sized.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"        # attn | mamba
    attn: str = "full"        # full | swa   (when kind == attn)
    mlp: str = "dense"        # dense | moe | none


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    head_dim: Optional[int] = None
    window: int = 0                   # swa window
    attn_softcap: float = 0.0         # gemma2: 50.0
    final_softcap: float = 0.0        # gemma2: 30.0
    mlp_act: str = "silu"
    gated_mlp: bool = True
    rope_theta: float = 10_000.0
    use_rope: bool = True
    norm: str = "rms"                 # rms | layer
    rms_plus_one: bool = False        # gemma (1 + w) scaling
    post_norms: bool = False          # gemma2 post-attn/post-mlp norms
    embed_scale: bool = False         # gemma multiplies embed by sqrt(D)
    tie_embeddings: bool = True
    qkv_bias: bool = False
    # --- moe ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- ssm (mamba-1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256              # seq chunk for the selective scan
    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    # --- modality frontend (STUB: precomputed embeddings via input_specs) ---
    frontend: str = "none"            # none | vision | audio
    num_frontend_tokens: int = 0      # llava: 576 patch embeddings
    frontend_offset: int = 1          # splice position for vision tokens
    # learned-position table length (used when use_rope=False, e.g. whisper
    # decoder; sized to the largest assigned decode shape, see DESIGN.md)
    max_learned_pos: int = 0
    # explicit long_500k capability (assignment: run for SSM / hybrid /
    # window-bounded archs; skip pure full-attention archs).  Hybrids like
    # jamba qualify even though their few attn layers are full (state is
    # O(S) on 1/8 of layers, not O(S^2) compute per token).
    long_context: bool = False
    # --- numerics / perf knobs (the §Perf hillclimb turns these) ---
    dtype: str = "bfloat16"
    remat: str = "full"               # none | full | dots
    scan_unroll: int = 1
    attn_impl: str = "blocked"    # ref | blocked | flash(Pallas, TPU)
    use_mamba_kernel: bool = False

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % self.period == 0, \
            f"{self.name}: n_layers {self.n_layers} % pattern {self.period}"
        return self.n_layers // self.period

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return math.ceil(self.d_model / 16)

    @property
    def subquadratic(self) -> bool:
        """True iff no layer needs an unbounded-length KV cache."""
        return all(
            s.kind == "mamba" or (s.attn == "swa" and self.window > 0)
            for s in self.pattern)

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def kv_cache_len(self, spec: LayerSpec, seq_len: int) -> int:
        if spec.attn == "swa" and self.window > 0:
            return min(self.window, seq_len)
        return seq_len

    # -- parameter count (for MODEL_FLOPS = 6*N*D roofline term) -----------
    def param_count(self, active_only: bool = False) -> int:
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        H, KV, dh = self.n_heads, self.n_kv_heads, self.head_dim_
        total = V * D  # embed
        if not self.tie_embeddings:
            total += V * D
        for spec in self.pattern:
            per = 0
            if spec.kind == "attn":
                per += D * (H + 2 * KV) * dh + H * dh * D
            else:
                I, N, R = self.d_inner, self.ssm_state, self.dt_rank
                per += D * 2 * I + self.ssm_conv * I + I * (R + 2 * N) \
                    + R * I + I * N + I + I * D
            if spec.mlp == "dense":
                per += D * F * (3 if self.gated_mlp else 2)
            elif spec.mlp == "moe":
                e = self.top_k if active_only else self.n_experts
                per += D * self.n_experts  # router (always live)
                per += e * D * F * (3 if self.gated_mlp else 2)
            total += per * self.n_blocks
        if self.enc_layers:
            per = D * (H + 2 * KV) * dh + H * dh * D  # enc self-attn
            per += D * F * (3 if self.gated_mlp else 2)
            total += per * self.enc_layers
            # decoder cross-attention adds another attn block per layer
            total += (D * (H + 2 * KV) * dh + H * dh * D) * self.n_layers
        return total

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=max(2 * self.period, self.period),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            window=min(self.window, 8) if self.window else 0,
            num_frontend_tokens=min(self.num_frontend_tokens, 4),
        )
        if self.n_experts:
            kw["n_experts"] = 4
            kw["top_k"] = min(self.top_k, 2)
        if self.ssm_state:
            kw["ssm_state"] = 4
        if self.enc_layers:
            kw["enc_layers"] = 2
        return self.with_(**kw)

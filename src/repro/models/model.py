"""Model API: loss, step builders, and dry-run input specs.

``make_train_step`` / ``make_prefill`` / ``make_serve_step`` return pure
functions suitable for jax.jit with in/out shardings from
``repro.parallel.sharding`` -- the launchers (train/serve/dryrun) and the
smoke tests all consume models exclusively through this module.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import LogicalRules, shard
from .config import ModelConfig
from . import transformer as T

PyTree = Any


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(cfg: ModelConfig, logits: jax.Array, tokens: jax.Array,
            aux: jax.Array, rules: Optional[LogicalRules] = None) -> jax.Array:
    """Next-token cross-entropy (fp32) + MoE aux. logits: (B,S,V).

    Sharding-aware formulation: targets are shifted (not the logits, which
    would break the sequence-parallel partition) and the gold logit is a
    one-hot contraction over the vocab-sharded axis (a take_along_axis here
    would all-gather the full fp32 logits onto every device -- measured as
    the single largest temp of the naive lowering)."""
    B, S, V = logits.shape
    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = jnp.concatenate(
        [jnp.ones((B, S - 1), jnp.float32), jnp.zeros((B, 1), jnp.float32)],
        axis=1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    oh = jax.nn.one_hot(targets, V, dtype=logits.dtype)
    oh = shard(oh, rules, "batch", "act_seq", "tp")
    gold = jnp.sum(logits * oh, axis=-1)
    nll = jnp.sum((logz - gold) * mask) / jnp.sum(mask)
    return nll + cfg.router_aux_coef * aux


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def make_forward(cfg: ModelConfig, rules: Optional[LogicalRules] = None
                 ) -> Callable[..., tuple[jax.Array, jax.Array]]:
    if cfg.is_encdec:
        def fwd(params, batch):
            return T.forward_encdec(cfg, params, batch["frame_embeds"],
                                    batch["tokens"], rules)
    elif cfg.frontend == "vision":
        def fwd(params, batch):
            return T.forward_lm(cfg, params, batch["tokens"], rules,
                                image_embeds=batch["image_embeds"])
    else:
        def fwd(params, batch):
            return T.forward_lm(cfg, params, batch["tokens"], rules)
    return fwd


def make_hidden_forward(cfg: ModelConfig, rules: Optional[LogicalRules] = None):
    if cfg.is_encdec:
        def fwd(params, batch):
            return T.forward_encdec_hidden(cfg, params, batch["frame_embeds"],
                                           batch["tokens"], rules)
    else:
        def fwd(params, batch):
            return T.forward_lm_hidden(cfg, params, batch, rules)
    return fwd


def make_loss_fn(cfg: ModelConfig, rules: Optional[LogicalRules] = None,
                 seq_chunk: int = 0):
    """Chunked-vocab cross-entropy over the hidden states.

    The logits tensor never materializes at full sequence length: each
    seq_chunk is gathered (small) and unembedded with the VOCAB dim sharded
    over the model axis -- (B_l, 512, V/16) fp32 live instead of
    (B_l, S, V) (4.1 GB/dev at gemma2's 256k vocab, measured)."""
    hfwd = make_hidden_forward(cfg, rules)

    def loss_fn(params, batch):
        x, aux = hfwd(params, batch)
        tokens = batch["tokens"]
        B, S = tokens.shape
        V = cfg.vocab_size
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        targets = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
        nll_sum = jnp.zeros((), jnp.float32)
        if seq_chunk > 0:
            step = min(seq_chunk, S)
        else:
            # adaptive: bound each chunk's global fp32 logits to ~96 GB
            # (fewer chunks => fewer live embed-grad partials, measured)
            n_chunks = max(1, -(-B * S * V * 4 // (96 * 10**9)))
            step = max(-(-S // n_chunks), 1)
        for s0 in range(0, S, step):
            xe = x[:, s0: s0 + step]
            xe = shard(xe, rules, "batch", None, None)   # gather the chunk
            lg = jnp.einsum("bsd,vd->bsv", xe, table.astype(xe.dtype))
            lg = shard(lg, rules, "batch", None, "tp")   # vocab-sharded
            lg = lg.astype(jnp.float32)
            if cfg.final_softcap:
                lg = cfg.final_softcap * jnp.tanh(lg / cfg.final_softcap)
            tg = targets[:, s0: s0 + step]
            logz = jax.nn.logsumexp(lg, axis=-1)
            vocab_sharded = (rules is not None and rules.mesh is not None
                             and len(rules.spec_for_shape(
                                 ("batch", None, "tp"), lg.shape)) > 2)
            if vocab_sharded:
                oh = jax.nn.one_hot(tg, V, dtype=lg.dtype)
                oh = shard(oh, rules, "batch", None, "tp")
                gold = jnp.sum(lg * oh, axis=-1)
            else:
                # local gather: no one-hot materialization needed when the
                # vocab dim is unsharded (dp_zero3 layouts)
                gold = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
            nll = logz - gold
            if s0 + step >= S:  # mask the final position (no next token)
                c = tg.shape[1]  # last chunk may be shorter than step
                nll = nll * jnp.concatenate(
                    [jnp.ones((B, c - 1), jnp.float32),
                     jnp.zeros((B, 1), jnp.float32)], axis=1)
            nll_sum = nll_sum + jnp.sum(nll)
        loss = nll_sum / (B * (S - 1))
        return loss + cfg.router_aux_coef * aux

    return loss_fn


def make_train_step(cfg: ModelConfig, optimizer,
                    rules: Optional[LogicalRules] = None):
    """Returns train_step(state, batch) -> (state, metrics).
    ``optimizer`` is a repro.train.optimizer.Optimizer."""
    loss_fn = make_loss_fn(cfg, rules)

    def train_step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        new_state = optimizer.apply(state, grads)
        # shape-preserving reduction: a vdot/reshape here would force an
        # all-gather of every (sharded) gradient stack (measured: +10 GB/dev)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return new_state, {"loss": loss, "grad_norm": gnorm,
                           "step": new_state.step}

    return train_step


def make_prefill(cfg: ModelConfig, rules: Optional[LogicalRules] = None):
    """Full-sequence forward (inference-prefill shape class).  Returns the
    LAST position's logits (B, 1, V) -- the serving semantic; emitting the
    full (B, S, V) tensor would make the step output 16 GB/device at
    gemma2 x prefill_32k for logits nobody reads."""
    if cfg.is_encdec:
        def prefill(params, batch):
            enc = T.encode(cfg, params, batch["frame_embeds"], rules)
            logits, _ = T.decode_train(cfg, params, enc,
                                       batch["tokens"], rules)
            return logits[:, -1:, :]
        return prefill

    def prefill(params, batch):
        tokens = batch["tokens"]
        x = T.embed_inputs(cfg, params, batch, rules)
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        x, _ = T._scan_blocks(cfg, x, params["blocks"], rules, positions)
        x = T._norm(cfg, x, params, "final")
        x = x[:, -1:, :]
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        from . import layers as L
        return L.unembed(x, table, cfg.final_softcap, rules)

    return prefill


def make_serve_step(cfg: ModelConfig, rules: Optional[LogicalRules] = None):
    """One-token decode against a KV/state cache of seq_len."""
    if cfg.is_encdec:
        def serve_step(params, cache, batch):
            logits, new_cache = T.decode_step_encdec(
                cfg, params, cache, batch["enc_out"], batch["token"],
                batch["pos"], rules)
            return logits, new_cache
    else:
        def serve_step(params, cache, batch):
            logits, new_cache = T.decode_step_lm(
                cfg, params, cache, batch["token"], batch["pos"], rules)
            return logits, new_cache
    return serve_step


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct only -- never allocates)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, seq_len: int, global_batch: int,
                mode: str) -> dict[str, Any]:
    """Stand-ins for every model input of a (arch x shape) cell.

    mode: "train" | "prefill" | "decode".  Frontend stubs: vlm cells get
    precomputed patch embeddings, audio cells get frame embeddings
    (per the assignment: the conv/patch frontend is NOT modeled)."""
    B, S, D = global_batch, seq_len, cfg.d_model
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    if mode in ("train", "prefill"):
        if cfg.is_encdec:
            return {"frame_embeds": jax.ShapeDtypeStruct((B, S, D), dt),
                    "tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.frontend == "vision":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "image_embeds": jax.ShapeDtypeStruct(
                        (B, cfg.num_frontend_tokens, D), dt)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    assert mode == "decode"
    batch = {"token": jax.ShapeDtypeStruct((B, 1), i32),
             "pos": jax.ShapeDtypeStruct((), i32)}
    if cfg.is_encdec:
        # encoder ran at prefill; decode sees its output (standard 30 s
        # window = 1500 frames), while the self-attn cache spans seq_len.
        batch["enc_out"] = jax.ShapeDtypeStruct((B, 1500, D), dt)
    return batch


def abstract_cache(cfg: ModelConfig, global_batch: int, seq_len: int):
    return jax.eval_shape(
        lambda: T.init_cache(cfg, global_batch, seq_len))


def batch_logical(cfg: ModelConfig, mode: str) -> dict[str, tuple]:
    """Logical sharding axes for each input (matched to input_specs)."""
    if mode in ("train", "prefill"):
        out: dict[str, tuple] = {"tokens": ("batch", None)}
        if cfg.is_encdec:
            out["frame_embeds"] = ("batch", None, None)
        if cfg.frontend == "vision":
            out["image_embeds"] = ("batch", None, None)
        return out
    out = {"token": ("batch", None), "pos": ()}
    if cfg.is_encdec:
        out["enc_out"] = ("batch", None, None)
    return out

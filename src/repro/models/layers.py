"""Model building blocks: norms, RoPE, GQA attention variants, MLPs.

Everything is a pure function over explicit parameter dicts (no flax): the
framework owns parameter structure so it can stack layers for lax.scan and
attach logical shardings uniformly.  Attention supports the assigned-arch
variants: full / sliding-window (SWA) / local+global alternating, logit
softcapping (gemma2), GQA with any kv-head count, and an optional Pallas
flash-attention path (repro.kernels) for the TPU target.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import LogicalRules, shard


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    """RMSNorm with fp32 *accumulation* but no full-tensor upcast.

    ``x.astype(f32)`` here puts a fp32 copy of the residual stream in the
    graph; XLA then keeps fp32 shadows of the whole saved-carry stack
    (+4-6 GB/device at every train cell, measured).  The variance is
    instead accumulated in fp32 via einsum's preferred_element_type; the
    elementwise rescale stays in the compute dtype."""
    dt = x.dtype
    # reduce a DERIVED value (x*x), never x itself: reduce/einsum upcasts of
    # the raw residual give XLA license to convert the whole saved-carry
    # stack to fp32 outside the layer loop (measured +4-6 GB/dev).
    var = jnp.sum(jnp.square(x), axis=-1, keepdims=True,
                  dtype=jnp.float32) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps)
    w = (1.0 + scale.astype(jnp.float32)) if plus_one else scale.astype(jnp.float32)
    return x * (inv * w.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    """LayerNorm, same no-upcast discipline as rms_norm."""
    dt = x.dtype
    D = x.shape[-1]
    # pairwise bf16 pre-sum => the fp32 reduce consumes a DERIVED tensor
    # (see rms_norm); one bf16 add costs <=1 ulp.
    pair = x.reshape(x.shape[:-1] + (D // 2, 2))
    s2 = pair[..., 0] + pair[..., 1]
    mu = (jnp.sum(s2, axis=-1, dtype=jnp.float32) / D)[..., None]
    sq = (jnp.sum(jnp.square(x), axis=-1, dtype=jnp.float32) / D)[..., None]
    var = jnp.maximum(sq - mu * mu, 0.0)
    inv = jax.lax.rsqrt(var + eps)
    xc = x - mu.astype(dt)
    return xc * (inv * scale.astype(jnp.float32)).astype(dt) \
        + bias.astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

NEG_INF = -2.0 ** 30  # large-negative that survives bf16 softmax


@dataclasses.dataclass(frozen=True)
class AttnVariant:
    kind: str = "full"            # full | swa
    window: int = 0               # swa window (keys kept: window, inclusive)
    softcap: float = 0.0          # gemma2 attn logit softcap
    causal: bool = True           # False for encoder self-attention


def _softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


def attention_mask(q_pos: jax.Array, k_pos: jax.Array, variant: AttnVariant) -> jax.Array:
    """(.., Sq, Sk) boolean validity mask from absolute positions."""
    ok = jnp.ones(q_pos.shape[-1:] + k_pos.shape[-1:], dtype=bool)
    if variant.causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if variant.kind == "swa" and variant.window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - variant.window
    return ok


def gqa_attention(
    q: jax.Array,             # (B, Sq, H, Dh)
    k: jax.Array,             # (B, Sk, KV, Dh)
    v: jax.Array,             # (B, Sk, KV, Dh)
    q_pos: jax.Array,         # (Sq,)
    k_pos: jax.Array,         # (Sk,)
    variant: AttnVariant,
    k_valid: Optional[jax.Array] = None,   # (B, Sk) extra validity (cache fill)
) -> jax.Array:
    """Reference GQA attention (fp32 softmax). Returns (B, Sq, H, Dh)."""
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, Dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(Dh).astype(jnp.float32)
    logits = _softcap(logits, variant.softcap)
    mask = attention_mask(q_pos, k_pos, variant)               # (Sq, Sk)
    if k_valid is not None:
        mask = mask[None] & k_valid[:, None, :]                # (B, Sq, Sk)
        logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    else:
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def blocked_attention(
    q: jax.Array,             # (B, Sq, H, Dh)
    k: jax.Array,             # (B, Sk, KV, Dh)
    v: jax.Array,             # (B, Sk, KV, Dh)
    q_pos: jax.Array,
    k_pos: jax.Array,
    variant: AttnVariant,
    block_k: int = 1024,
) -> jax.Array:
    """Online-softmax attention chunked over keys (the XLA analogue of the
    Pallas flash kernel): peak intermediate is (B,H,Sq,block_k) instead of
    (B,H,Sq,Sk).  This is the shipped lowering path for big configs; on
    real TPUs the Pallas kernel (attn_impl='flash') replaces it."""
    B, Sq, H, Dh = q.shape
    KV, Sk = k.shape[2], k.shape[2]
    Sk = k.shape[1]
    G = H // KV
    bk = min(block_k, Sk)
    pad = (-Sk) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=2**30)
    nk = (Sk + pad) // bk
    qg = (q.reshape(B, Sq, KV, G, Dh).astype(jnp.float32)
          / jnp.sqrt(Dh).astype(jnp.float32))
    kc = k.reshape(B, nk, bk, KV, Dh)
    vc = v.reshape(B, nk, bk, KV, Dh)
    kp = k_pos.reshape(nk, bk)

    def chunk(carry, kci, vci, kpi):
        m, l, acc = carry
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kci.astype(jnp.float32))
        s = _softcap(s, variant.softcap)
        ok = jnp.ones((Sq, bk), bool)
        if variant.causal:
            ok &= kpi[None, :] <= q_pos[:, None]
        if variant.kind == "swa" and variant.window > 0:
            ok &= kpi[None, :] > q_pos[:, None] - variant.window
        ok &= (kpi < 2**30)[None, :]
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vci.astype(jnp.float32))
        return (m_new, l, acc)

    m = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l = jnp.zeros((B, KV, G, Sq), jnp.float32)
    acc = jnp.zeros((B, KV, G, Sq, Dh), jnp.float32)
    # static unroll: keeps HLO flop counting honest (a lax.scan body is
    # costed once by XLA cost analysis) and lets XLA schedule chunks freely.
    # per-chunk checkpoint: backward recomputes one chunk's (bq x bk) score
    # tile at a time instead of holding all nk of them live.
    ck = jax.checkpoint(chunk)
    for i in range(nk):
        m, l, acc = ck((m, l, acc), kc[:, i], vc[:, i], kp[i])
    out = acc / jnp.where(l > 0, l, 1.0)[..., None]
    out = jnp.moveaxis(out.reshape(B, KV * G, Sq, Dh), 1, 2)
    return out.astype(q.dtype)


def attention_block(
    x: jax.Array,                      # (B, S, D)
    p: dict,                           # wq, wk, wv, wo
    positions: jax.Array,              # (S,)
    variant: AttnVariant,
    rope_theta: float,
    rules: Optional[LogicalRules] = None,
    use_rope: bool = True,
    impl: str = "blocked",             # ref | blocked | flash
) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    q = shard(q, rules, "batch", "act_seq", "tp", None)
    k = shard(k, rules, "batch", None, None, None)
    v = shard(v, rules, "batch", None, None, None)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if impl == "flash":
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(
            q, k, v, causal=variant.causal,
            window=variant.window if variant.kind == "swa" else 0,
            softcap=variant.softcap)
    elif impl == "blocked":
        out = blocked_attention(q, k, v, positions, positions, variant)
    else:
        out = gqa_attention(q, k, v, positions, positions, variant)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return shard(y, rules, "batch", "act_seq", None)


def attention_decode(
    x: jax.Array,                      # (B, 1, D)
    p: dict,
    cache_k: jax.Array,                # (B, S_cache, KV, Dh)
    cache_v: jax.Array,
    pos: jax.Array,                    # scalar int32: absolute position
    variant: AttnVariant,
    rope_theta: float,
    use_rope: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode with in-place cache update.

    SWA layers use the cache as a ring buffer of size min(window, S_cache)
    (this is what makes long_500k decode sub-quadratic in memory for
    window-bounded archs)."""
    B, _, D = x.shape
    S_cache = cache_k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if use_rope:
        pos_arr = pos[None] if pos.ndim == 0 else pos
        q = apply_rope(q, pos_arr, rope_theta)
        k = apply_rope(k, pos_arr, rope_theta)
    # ring placement: identity while pos < S_cache, wraps afterwards (SWA
    # archs size the cache to the window; full-attn caches cover max_seq).
    slot = pos % S_cache
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    # absolute position of every cache slot under ring placement
    idx = jnp.arange(S_cache, dtype=jnp.int32)
    wraps = (pos // S_cache)
    k_pos = jnp.where(idx <= slot, wraps * S_cache + idx,
                      (wraps - 1) * S_cache + idx)
    k_valid = k_pos >= 0
    if variant.kind == "swa" and variant.window > 0:
        k_valid &= k_pos > pos - variant.window
    KV, Dh = k.shape[2], k.shape[3]
    H = q.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, Dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        cache_k.astype(jnp.float32)) / jnp.sqrt(Dh).astype(jnp.float32)
    logits = _softcap(logits, variant.softcap)
    valid = k_valid & (k_pos <= pos)
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, cache_v.astype(jnp.float32))
    out = out.reshape(B, 1, H, Dh).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, cache_k, cache_v


def cross_attention_block(
    x: jax.Array,                      # (B, Sq, D) decoder states
    enc: jax.Array,                    # (B, Sk, D) encoder output
    p: dict,                           # wq, wk, wv, wo
    rules: Optional[LogicalRules] = None,
    impl: str = "blocked",
) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"].astype(x.dtype))
    Sq, Sk = x.shape[1], enc.shape[1]
    variant = AttnVariant(kind="full", causal=False)
    if impl == "blocked" and Sq > 1:
        out = blocked_attention(q, k, v, jnp.arange(Sq), jnp.arange(Sk),
                                variant)
    else:
        out = gqa_attention(q, k, v, jnp.arange(Sq), jnp.arange(Sk), variant)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_block(x: jax.Array, p: dict, act: str,
              rules: Optional[LogicalRules] = None) -> jax.Array:
    """Gated (silu/gelu "glu" style) or plain (gelu / squared-relu) MLP.
    Presence of p["w_gate"] selects gated."""
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
        g = shard(g, rules, "batch", None, "tp")
        h = _activate(g, act) * u
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
        h = shard(h, rules, "batch", None, "tp")
        h = _activate(h, act)
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    return shard(y, rules, "batch", None, None)


def _activate(x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if act == "relu2":  # nemotron squared ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {act}")


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed(tokens: jax.Array, table: jax.Array,
          rules: Optional[LogicalRules] = None,
          scale_by_sqrt_dim: bool = False) -> jax.Array:
    x = jnp.take(table, tokens, axis=0)
    if scale_by_sqrt_dim:
        x = x * jnp.sqrt(table.shape[-1]).astype(x.dtype)
    return shard(x, rules, "batch", None, None)


def unembed(x: jax.Array, table: jax.Array, softcap: float = 0.0,
            rules: Optional[LogicalRules] = None) -> jax.Array:
    logits = jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype))
    logits = shard(logits, rules, "batch", "act_seq", "tp")
    logits = _softcap(logits.astype(jnp.float32), softcap)
    return logits

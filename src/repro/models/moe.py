"""Mixture-of-Experts layer (GShard/Switch-style capacity routing).

Top-k softmax router with renormalized gates, capacity-bounded dispatch via
one-hot matmuls (MXU-friendly: dispatch/combine are dense einsums, which is
the TPU-native formulation -- no scatter), experts shardable over the mesh
"expert" logical axis (EP) when E divides the axis, else expert FFNs fall
back to TP on d_ff (mixtral: 8 experts on a 16-way model axis).

HLO-FLOPs note for §Roofline: capacity routing makes compiled FLOPs
~ capacity_factor * active-expert FLOPs, not n_experts/top_k of them.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import LogicalRules, shard


def router_probs(x: jax.Array, w_router: jax.Array, top_k: int
                 ) -> tuple[jax.Array, jax.Array]:
    """Returns (gates (T, k) fp32 renormalized, idx (T, k) int32)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx


def moe_block(
    x: jax.Array,                 # (B, S, D)
    p: dict,                      # w_router (D,E), w_gate/w_up (E,D,F), w_down (E,F,D)
    top_k: int,
    act: str = "silu",
    capacity_factor: float = 1.25,
    rules: Optional[LogicalRules] = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux_loss scalar fp32)."""
    from .layers import _activate

    B, S, D = x.shape
    E = p["w_router"].shape[-1]
    T = B * S
    xt = x.reshape(T, D)
    gates, idx = router_probs(xt, p["w_router"], top_k)        # (T,k)

    cap = int(max(top_k * capacity_factor * ((T + E - 1) // E), 1))
    # position of each (token, choice) in its expert's queue
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)           # (T,k,E)
    flat = onehot.reshape(T * top_k, E)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(T, top_k, E)
    pos_in_expert = jnp.sum(pos_in_expert * onehot, axis=-1)   # (T,k)
    keep = pos_in_expert < cap                                  # drop overflow
    gates = gates * keep.astype(gates.dtype)

    # dispatch tensor (T, E, cap) -- one-hot matmul formulation
    slot_oh = jax.nn.one_hot(jnp.where(keep, pos_in_expert, cap), cap + 1,
                             dtype=x.dtype)[..., :cap]          # (T,k,cap)
    dispatch = jnp.einsum("tke,tkc->tec", onehot.astype(x.dtype), slot_oh)
    combine = jnp.einsum("tk,tke,tkc->tec", gates.astype(x.dtype),
                         onehot.astype(x.dtype), slot_oh)

    ex_in = jnp.einsum("tec,td->ecd", dispatch, xt)             # (E,cap,D)
    ex_in = shard(ex_in, rules, "expert", None, None)
    if "w_gate" in p:
        g = jnp.einsum("ecd,edf->ecf", ex_in, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("ecd,edf->ecf", ex_in, p["w_up"].astype(x.dtype))
        h = _activate(g, act) * u
    else:
        h = _activate(jnp.einsum("ecd,edf->ecf", ex_in,
                                 p["w_up"].astype(x.dtype)), act)
    h = shard(h, rules, "expert", None, "tp")
    ex_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    ex_out = shard(ex_out, rules, "expert", None, None)
    out = jnp.einsum("tec,ecd->td", combine, ex_out).reshape(B, S, D)
    out = shard(out, rules, "batch", None, None)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(onehot.sum(1).astype(jnp.float32), axis=0)   # fraction routed
    pe = jnp.mean(jax.nn.softmax(jnp.einsum(
        "td,de->te", xt.astype(jnp.float32),
        p["w_router"].astype(jnp.float32)), axis=-1), axis=0)
    aux = E * jnp.sum(me * pe) / top_k
    return out, aux


# ---------------------------------------------------------------------------
# Production path: expert-parallel MoE via shard_map (sort+gather routing)
# ---------------------------------------------------------------------------
#
# The capacity-einsum dispatch above is the *reference*: its one-hot matmuls
# are O(tokens x E x capacity) -- measured at ~670x the active-expert FLOPs
# for qwen3 -- fine for tiny tests, unusable at scale.  The production path
# routes with sort + gather (zero-FLOP dispatch, local to each device) and
# moves tokens with all_to_all over the model axis when experts divide it
# (EP: qwen3 128e, jamba 16e), falling back to tensor-parallel expert FFNs +
# psum when they do not (mixtral 8e on a 16-way axis).  ZeRO-3 weight
# gathers are explicit all_gathers inside the shard_map.

def _local_route(xt, gates, idx, E: int, capacity: int):
    """Sort+gather dispatch on one device's tokens.
    xt: (T, D); gates/idx: (T, K).  Returns (disp (E, C, D), combine info)."""
    T, D = xt.shape
    K = idx.shape[1]
    flat_e = idx.reshape(-1)                          # (T*K,)
    order = jnp.argsort(flat_e)
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    # slot -> sorted position -> (token, k) pair
    src = starts[:, None] + jnp.arange(capacity)[None, :]         # (E, C)
    valid = jnp.arange(capacity)[None, :] < jnp.minimum(counts, capacity)[:, None]
    pair = order[jnp.clip(src, 0, T * K - 1)]                     # (E, C)
    disp = xt[pair // K] * valid[..., None].astype(xt.dtype)      # (E, C, D)
    # combine side: position of each pair within its expert run
    inv = jnp.zeros((T * K,), jnp.int32).at[order].set(jnp.arange(T * K))
    c_of_pair = inv - starts[flat_e]                              # (T*K,)
    in_cap = c_of_pair < capacity
    return disp, (flat_e, jnp.clip(c_of_pair, 0, capacity - 1), in_cap)


def _combine(expert_out, combine_info, gates, T: int, K: int):
    """expert_out: (E, C, D) -> (T, D) gate-weighted sum."""
    flat_e, c_of_pair, in_cap = combine_info
    picked = expert_out[flat_e, c_of_pair]                        # (T*K, D)
    picked = picked * in_cap[:, None].astype(picked.dtype)
    picked = picked.reshape(T, K, -1)
    return jnp.einsum("tk,tkd->td", gates.astype(picked.dtype), picked)


def moe_block_sharded(
    x: jax.Array,                 # (B, S, D)
    p: dict,
    cfg,                          # ModelConfig
    rules: Optional[LogicalRules],
) -> tuple[jax.Array, jax.Array]:
    """EP/TP MoE over the mesh; falls back to moe_block without one."""
    if rules is None or rules.mesh is None:
        return moe_block(x, p, cfg.top_k, cfg.mlp_act, cfg.capacity_factor,
                         rules)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = rules.mesh
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = axis_sizes.get("model", 1)
    E, K, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    ep = "model" in mesh.axis_names and E % model_n == 0 and model_n > 1
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    B, S, D = x.shape
    if ep:
        # EP: every rank routes its own (seq-sharded) tokens; experts move.
        x_spec = rules.spec_for_shape(("batch", "act_seq", None), (B, S, D))
    else:
        # expert-TP fallback: all model ranks must hold the SAME tokens --
        # each computes an F-slice of every local token and the partial
        # D-outputs are psum'ed (Megatron row/column split).  Seq-sharding
        # over model here would sum partials of DIFFERENT tokens.
        x_spec = rules.spec_for_shape(("batch", None, None), (B, S, D))
    def pspec(lg, shape):
        return rules.spec_for_shape(lg, tuple(shape))

    gated = "w_gate" in p
    w_specs = {k: pspec(lg, p[k].shape) for k, lg in {
        "w_router": (None, None),
        "w_up": ("expert", "fsdp", "tp"),
        "w_down": ("expert", "tp", "fsdp"),
        **({"w_gate": ("expert", "fsdp", "tp")} if gated else {}),
    }.items()}

    # local token count (static): product of unsharded extents
    def _local(n, entry):
        sz = 1
        for a in (entry if isinstance(entry, tuple) else (entry,)) if entry else ():
            sz *= axis_sizes[a]
        return n // sz
    Bl = _local(B, x_spec[0] if len(x_spec) > 0 else None)
    Sl = _local(S, x_spec[1] if len(x_spec) > 1 else None)
    Tl = Bl * Sl
    C = max(min(int(-(-Tl * K * cf // E)), Tl * K), 4)

    # which (weight, dim) keeps its model-axis shard inside the body:
    #   EP:        the expert dim (dim 0) -- experts live on their rank
    #   expert-TP: the F dims (w_up/w_gate dim 2, w_down dim 1) -- partial
    #              outputs are psum'ed
    def _axes_of(spec, i):
        e = spec[i] if i < len(spec) else None
        return (e,) if isinstance(e, str) else tuple(e or ())

    tp_f = (not ep) and "model" in _axes_of(w_specs["w_down"], 1)

    def _keep(name: str, dim: int, axis: str) -> bool:
        if axis != "model":
            return False
        if ep and dim == 0:
            return True
        if tp_f and ((name in ("w_up", "w_gate") and dim == 2)
                     or (name == "w_down" and dim == 1)):
            return True
        return False

    def gathered(w, name, spec):
        """ZeRO-3 gather inside the shard_map: reassemble every sharded dim
        except the ones the algorithm keeps distributed (see _keep)."""
        for axis_i, entry in enumerate(spec):
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                if not _keep(name, axis_i, a):
                    w = jax.lax.all_gather(w, a, axis=axis_i, tiled=True)
        return w

    def body(xl, w_router, w_up, w_down, *rest):
        w_gate = rest[0] if gated else None
        w_up = gathered(w_up, "w_up", w_specs["w_up"])
        w_down = gathered(w_down, "w_down", w_specs["w_down"])
        if gated:
            w_gate = gathered(w_gate, "w_gate", w_specs["w_gate"])
        bl, sl, d = xl.shape
        xt = xl.reshape(bl * sl, d)
        gates, idx = router_probs(xt, w_router, K)
        disp, info = _local_route(xt, gates.astype(xt.dtype), idx, E, C)
        if ep:
            # EP: split experts across the model axis, concat capacity
            disp = jax.lax.all_to_all(disp, "model", split_axis=0,
                                      concat_axis=1, tiled=True)
        from .layers import _activate
        if gated:
            h = _activate(jnp.einsum("ecd,edf->ecf", disp, w_gate.astype(xt.dtype)),
                          cfg.mlp_act) * jnp.einsum("ecd,edf->ecf", disp,
                                                    w_up.astype(xt.dtype))
        else:
            h = _activate(jnp.einsum("ecd,edf->ecf", disp,
                                     w_up.astype(xt.dtype)), cfg.mlp_act)
        out = jnp.einsum("ecf,efd->ecd", h, w_down.astype(xt.dtype))
        if ep:
            out = jax.lax.all_to_all(out, "model", split_axis=1,
                                     concat_axis=0, tiled=True)
        elif tp_f:
            # expert-TP fallback: partial sums over the f-sharded dim
            out = jax.lax.psum(out, "model")
        y = _combine(out, info, gates, bl * sl, K).reshape(bl, sl, d)
        # Switch aux loss from local stats, averaged over the mesh
        me = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(1), axis=0)
        pe = jnp.mean(jax.nn.softmax(jnp.einsum(
            "td,de->te", xt.astype(jnp.float32),
            w_router.astype(jnp.float32)), axis=-1), axis=0)
        aux = E * jnp.sum(me * pe) / K
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))
        return y, aux

    args = [x, p["w_router"], p["w_up"], p["w_down"]]
    in_specs = [x_spec, w_specs["w_router"], w_specs["w_up"], w_specs["w_down"]]
    if gated:
        args.append(p["w_gate"])
        in_specs.append(w_specs["w_gate"])
    y, aux = shard_map(
        body, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=(x_spec, P()), check_rep=False,
    )(*args)
    return y, aux


def moe_param_shapes(d_model: int, d_ff: int, n_experts: int,
                     gated: bool) -> dict[str, tuple[tuple[int, ...], tuple]]:
    """shape + logical axes for one MoE layer (leading layer-stack dim is
    added by the caller)."""
    shapes = {
        "w_router": ((d_model, n_experts), (None, None)),
        "w_up": ((n_experts, d_model, d_ff), ("expert", "fsdp", "tp")),
        "w_down": ((n_experts, d_ff, d_model), ("expert", "tp", "fsdp")),
    }
    if gated:
        shapes["w_gate"] = ((n_experts, d_model, d_ff), ("expert", "fsdp", "tp"))
    return shapes

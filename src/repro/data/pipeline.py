"""Diffusion-backed training input pipeline.

The paper's technique as a first-class training feature: dataset shards are
diffusable objects; per-step shard reads are dispatched by the Falkon-style
Dispatcher over host-worker executors with local caches.  Epoch N+1's
accesses hit the caches that epoch N populated -- the locality the paper
exploits (Figures 8-11) shows up here as store-byte reduction, measured by
tests/test_pipeline.py and examples/train_lm.py.

Pipeline = DiffusionRuntime (real threaded engine) + prefetch queue:
  * ``schedule`` maps step -> list of shard oids (seeded shuffle, repeats
    across epochs create the Table-2-style locality);
  * shard-read tasks resolve via local cache -> peer cache -> store;
  * fetched shards are sliced into (global_batch, seq_len+1) token blocks;
  * a background thread keeps ``prefetch_depth`` batches ready, overlapping
    data movement with train-step compute (the paper's overlap discipline).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.core.cache import EvictionPolicy
from repro.core.objects import Task
from repro.core.policies import DispatchPolicy
from repro.core.runtime import DiffusionRuntime, ObjectStore
from .dataset import ShardSpec, shard_oid, synthesize


@dataclass(frozen=True)
class PipelineConfig:
    global_batch: int
    seq_len: int
    n_hosts: int = 4
    policy: DispatchPolicy = DispatchPolicy.MAX_COMPUTE_UTIL
    cache_policy: EvictionPolicy = EvictionPolicy.LRU
    host_cache_bytes: int = 1 << 28
    prefetch_depth: int = 2
    seed: int = 0

    @property
    def tokens_per_batch(self) -> int:
        return self.global_batch * (self.seq_len + 1)


class DiffusionDataPipeline:
    def __init__(self, cfg: PipelineConfig, spec: ShardSpec,
                 store: Optional[ObjectStore] = None) -> None:
        assert spec.tokens_per_shard >= cfg.tokens_per_batch, \
            "shard must cover a global batch"
        self.cfg = cfg
        self.spec = spec
        self.store = store if store is not None else ObjectStore()
        self.objs = synthesize(spec, self.store)
        self.rt = DiffusionRuntime(
            n_executors=cfg.n_hosts, policy=cfg.policy,
            cache_policy=cfg.cache_policy,
            cache_capacity_bytes=cfg.host_cache_bytes, store=self.store,
            seed=cfg.seed)
        self.rt.configure_caches(cfg.host_cache_bytes, cfg.cache_policy)
        self._rng = np.random.default_rng(cfg.seed)
        self._q: "queue.Queue[tuple[int, np.ndarray]]" = queue.Queue(
            maxsize=cfg.prefetch_depth)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._schedule_state = 0

    # -- shard schedule -------------------------------------------------
    def shard_for_step(self, step: int) -> str:
        """Seeded shuffled epochs over shards: repeats across epochs give
        the workload its locality (the lever the paper's Figure 11 turns)."""
        n = self.spec.n_shards
        epoch, pos = divmod(step, n)
        rng = np.random.default_rng(self.cfg.seed * 7 + epoch)
        perm = rng.permutation(n)
        return shard_oid(int(perm[pos]))

    # -- batch materialization -------------------------------------------
    def _batch_from(self, tokens: np.ndarray, step: int) -> np.ndarray:
        need = self.cfg.tokens_per_batch
        rng = np.random.default_rng(self.cfg.seed * 13 + step)
        start = int(rng.integers(0, max(len(tokens) - need, 1)))
        flat = tokens[start:start + need]
        if len(flat) < need:  # wrap
            flat = np.concatenate([flat, tokens[: need - len(flat)]])
        return flat.reshape(self.cfg.global_batch, self.cfg.seq_len + 1)

    def fetch_step(self, step: int) -> np.ndarray:
        """Synchronous fetch of one global batch through diffusion."""
        oid = self.shard_for_step(step)
        task = Task(inputs=(oid,), fn=lambda inputs: next(iter(inputs.values())))
        self.rt.submit([task])
        assert self.rt.wait(120), "diffusion fetch timed out"
        if isinstance(task.result, Exception):
            raise task.result
        return self._batch_from(task.result, step)

    # -- prefetching iterator ----------------------------------------------
    def _producer(self, start_step: int, n_steps: int) -> None:
        try:
            for s in range(start_step, start_step + n_steps):
                if self._stop.is_set():
                    return
                self._q.put((s, self.fetch_step(s)))
        except BaseException as e:  # noqa: BLE001 - surface in the consumer
            # a dead producer must not leave batches() blocked on q.get()
            self._q.put((-1, e))

    def batches(self, start_step: int, n_steps: int
                ) -> Iterator[tuple[int, np.ndarray]]:
        self._thread = threading.Thread(
            target=self._producer, args=(start_step, n_steps), daemon=True)
        self._thread.start()
        for _ in range(n_steps):
            step, b = self._q.get()
            if isinstance(b, BaseException):
                raise b
            yield step, b

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self.rt.shutdown()

    # -- the paper's metrics ----------------------------------------------
    @property
    def ledger(self):
        return self.rt.ledger

    def stats(self) -> dict:
        lg = self.rt.ledger
        return {
            "bytes_local": lg.bytes_local,
            "bytes_cache_to_cache": lg.bytes_c2c,
            "bytes_store": lg.bytes_store,
            "local_hit_ratio": lg.local_hit_ratio,
            "global_hit_ratio": lg.global_hit_ratio,
            "store_reads": lg.store_reads,
        }

"""Synthetic tokenized shard store: the training-side persistent storage.

Immutable shards of tokenized documents (the FITS files of the training
world).  Shards are numpy arrays registered in a diffusion ObjectStore so
the pipeline's fetches flow through the paper's cache/scheduling machinery
and every byte is accounted local / cache-to-cache / store.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.objects import DataObject
from repro.core.runtime import ObjectStore


@dataclass(frozen=True)
class ShardSpec:
    n_shards: int
    tokens_per_shard: int
    vocab_size: int
    seed: int = 0

    @property
    def shard_bytes(self) -> int:
        return self.tokens_per_shard * 4


def shard_oid(i: int) -> str:
    return f"shard{i:06d}"


def synthesize(spec: ShardSpec, store: ObjectStore) -> list[DataObject]:
    """Materialize immutable token shards into the store.

    Content is a seeded Zipf-ish sample so losses are non-trivial and
    runs are reproducible."""
    objs = []
    for i in range(spec.n_shards):
        rng = np.random.default_rng(spec.seed * 1_000_003 + i)
        # zipf-like marginal over the vocab, bounded
        z = rng.zipf(1.3, size=spec.tokens_per_shard)
        tokens = (z % (spec.vocab_size - 2)).astype(np.int32) + 2
        obj = DataObject(shard_oid(i), spec.shard_bytes)
        store.put(obj, tokens)
        objs.append(obj)
    return objs

from .dataset import ShardSpec, shard_oid, synthesize
from .pipeline import DiffusionDataPipeline, PipelineConfig

__all__ = ["DiffusionDataPipeline", "PipelineConfig", "ShardSpec",
           "shard_oid", "synthesize"]

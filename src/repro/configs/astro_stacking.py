"""The paper's own application workload: SDSS DR5 image stacking (§5).

Table 2 workload characteristics (locality -> objects/files), file sizes
(2 MB compressed GZ / 6 MB uncompressed FIT), and the §5.2 stacking-code
profile used to calibrate per-task compute in the simulator:

  * calibration+interpolation+doStacking < 1 ms
  * radec2xy ~ 10-20% of total (we use 2 ms)
  * GZ decompress is CPU-bound (~40 ms for 2 MB -> 6 MB): single-CPU GZ is
    *slower* than FIT locally, but wins at scale because it moves 3x fewer
    bytes through the saturated shared FS (Figure 7's crossover).
"""
from __future__ import annotations

from dataclasses import dataclass

MB = 1_000_000

# Table 2: locality -> (num objects, num files)
WORKLOADS: dict[float, tuple[int, int]] = {
    1: (111_700, 111_700),
    1.38: (154_345, 111_699),
    2: (97_999, 49_000),
    3: (88_857, 29_620),
    4: (76_575, 19_145),
    5: (60_590, 12_120),
    10: (46_480, 4_650),
    20: (40_460, 2_025),
    30: (23_695, 790),
}

GZ_BYTES = 2 * MB
FIT_BYTES = 6 * MB

# §5.2-calibrated per-task CPU costs (seconds)
RADEC2XY_S = 2e-3
STACK_MATH_S = 1e-3          # calibration + interpolation + doStacking
GZ_DECOMPRESS_S = 40e-3
ROI_SHAPE = (100, 100)       # pixels per cutout


@dataclass(frozen=True)
class StackingWorkload:
    locality: float
    n_objects: int
    n_files: int
    compressed: bool

    @property
    def file_bytes(self) -> int:
        return GZ_BYTES if self.compressed else FIT_BYTES

    @property
    def compute_seconds(self) -> float:
        cpu = RADEC2XY_S + STACK_MATH_S
        if self.compressed:
            cpu += GZ_DECOMPRESS_S
        return cpu

    @property
    def ideal_cache_hit_ratio(self) -> float:
        """Paper's Figure 10 ideal: 1 - 1/locality."""
        return 1.0 - 1.0 / self.locality if self.locality > 0 else 0.0


def workload(locality: float, compressed: bool = True,
             scale: float = 1.0) -> StackingWorkload:
    n_obj, n_files = WORKLOADS[locality]
    return StackingWorkload(locality=locality,
                            n_objects=max(int(n_obj * scale), 1),
                            n_files=max(int(n_files * scale), 1),
                            compressed=compressed)

"""Architecture registry + the assigned input-shape grid.

``--arch <id>`` everywhere resolves through :func:`get_config`; the dry-run
iterates :func:`cells` (architecture x shape with documented skips)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

from repro.models.config import ModelConfig

from . import (falcon_mamba_7b, gemma2_27b, h2o_danube3_4b,
               jamba15_large_398b, llava_next_mistral_7b, mixtral_8x22b,
               nemotron4_15b, qwen3_moe_30b_a3b, starcoder2_15b, whisper_base)

REGISTRY: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (starcoder2_15b, h2o_danube3_4b, gemma2_27b, nemotron4_15b,
              llava_next_mistral_7b, falcon_mamba_7b, qwen3_moe_30b_a3b,
              mixtral_8x22b, whisper_base, jamba15_large_398b)
}

ARCH_IDS = tuple(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.long_context:
        return "pure full-attention arch: long_500k needs sub-quadratic attention (DESIGN.md §4)"
    return None


def cells(include_skipped: bool = False
          ) -> Iterator[tuple[ModelConfig, ShapeSpec, Optional[str]]]:
    """All 40 (arch x shape) cells; skipped ones carry their reason."""
    for cfg in REGISTRY.values():
        for shape in SHAPES.values():
            reason = skip_reason(cfg, shape)
            if reason is None or include_skipped:
                yield cfg, shape, reason

"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 -- GQA, squared-ReLU [arXiv:2402.16819].

Nemotron-4: plain (non-gated) squared-ReLU MLP, LayerNorm, RoPE, untied
256k embeddings."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256_000,
    head_dim=128,
    pattern=(LayerSpec(kind="attn", attn="full", mlp="dense"),),
    mlp_act="relu2",
    gated_mlp=False,
    norm="layer",
    rope_theta=1e4,
    tie_embeddings=False,
)

"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2 -- Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887].

Pattern period 8 = Jamba's 1:7 attention:mamba ratio (position 0 is the
attention layer); MoE replaces the dense MLP on every other layer
(positions 1,3,5,7 => 36 of 72 layers are MoE, matching Jamba's
every-2-layers placement).  ~398B total params; hybrid => the only
unbounded KV state is on the 9 attention layers => runs long_500k."""
from repro.models.config import LayerSpec, ModelConfig

_PATTERN = tuple(
    LayerSpec(kind="attn" if i == 0 else "mamba",
              attn="full",
              mlp="moe" if i % 2 == 1 else "dense")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    pattern=_PATTERN,
    n_experts=16,
    top_k=2,
    ssm_state=16,
    ssm_conv=4,
    ssm_chunk=512,
    ssm_expand=2,
    mlp_act="silu",
    gated_mlp=True,
    norm="rms",
    rope_theta=1e4,
    tie_embeddings=False,
    long_context=True,
)

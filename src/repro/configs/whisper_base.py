"""whisper-base [audio]: 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865 --
enc-dec, conv frontend (stub) [arXiv:2212.04356].

Encoder-decoder: 6 encoder layers (bidirectional self-attn over sinusoid-
positioned frame embeddings) + 6 decoder layers (causal self-attn + cross-
attn + MLP).  The conv1d/log-mel frontend is a STUB per the assignment:
input_specs() supplies precomputed frame embeddings.  LayerNorm, plain
GELU, learned decoder positions.  max_learned_pos is extended to 32k+1 so
the assigned decode_32k cell is well-defined (real whisper caps at 448
target positions -- extension documented in DESIGN.md §4).  Full attention
=> long_500k skipped."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    pattern=(LayerSpec(kind="attn", attn="full", mlp="dense"),),
    mlp_act="gelu",
    gated_mlp=False,
    norm="layer",
    use_rope=False,
    max_learned_pos=32_769,
    tie_embeddings=True,
    frontend="audio",
)

"""starcoder2-15b [dense]: 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152 -- GQA, RoPE [arXiv:2402.19173; hf].

StarCoder2 uses LayerNorm and a plain (non-gated) GELU MLP with 4x
expansion; 15.4B params with untied embeddings."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    pattern=(LayerSpec(kind="attn", attn="full", mlp="dense"),),
    mlp_act="gelu",
    gated_mlp=False,
    norm="layer",
    rope_theta=1e5,
    tie_embeddings=False,
)

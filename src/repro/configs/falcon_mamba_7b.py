"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attn-free) d_ff=0 vocab=65024,
ssm_state=16 -- mamba1 arch [arXiv:2410.05355].

Pure Mamba-1 stack: no attention, no MLP (the mamba block IS the layer:
in_proj expand 2x -> conv1d(4) -> selective scan -> gated out_proj).
Attention-free => O(1) decode state => runs long_500k natively."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    pattern=(LayerSpec(kind="mamba", mlp="none"),),
    ssm_state=16,
    ssm_conv=4,
    ssm_chunk=512,
    ssm_expand=2,
    norm="rms",
    tie_embeddings=False,
    long_context=True,
)

"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128e top-8 [hf:Qwen/Qwen3-30B-A3B].

Every layer is MoE (no shared expert in the 30B-A3B release); d_ff=768 is
the per-expert intermediate size.  ~30.5B total / ~3.3B active params.
(Qwen3's q/k-norm is not modeled -- noted in DESIGN.md.)  Full attention
=> long_500k skipped."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab_size=151_936,
    head_dim=128,
    pattern=(LayerSpec(kind="attn", attn="full", mlp="moe"),),
    n_experts=128,
    top_k=8,
    mlp_act="silu",
    gated_mlp=True,
    norm="rms",
    rope_theta=1e6,
    tie_embeddings=False,
)

"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, SWA [arXiv:2401.04088].

8 experts top-2 (~141B total / ~39B active), sliding-window attention per
the assignment (window 4096) => sub-quadratic => runs long_500k.  With 8
experts on a 16-way model axis, expert-parallel sharding does not divide;
the sharding rules fall back to TP over d_ff for this arch (DESIGN.md §5)."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    pattern=(LayerSpec(kind="attn", attn="swa", mlp="moe"),),
    window=4096,
    n_experts=8,
    top_k=2,
    mlp_act="silu",
    gated_mlp=True,
    norm="rms",
    rope_theta=1e6,
    tie_embeddings=False,
    long_context=True,
)

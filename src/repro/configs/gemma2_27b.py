"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000 -- local+global alternating, logit softcap [arXiv:2408.00118].

Gemma2 specifics modeled: 1:1 local(4096-window):global alternation
(pattern period 2), attn logit softcap 50, final logit softcap 30,
(1+w) RMSNorm with pre+post norms, sqrt(d_model) embedding scale, gated
GELU.  head_dim 128 (q width 4096 != d_model 4608).  Global layers are
full attention => NOT sub-quadratic => long_500k is skipped (DESIGN.md §4)."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab_size=256_000,
    head_dim=128,
    pattern=(LayerSpec(kind="attn", attn="swa", mlp="dense"),
             LayerSpec(kind="attn", attn="full", mlp="dense")),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    mlp_act="gelu",
    gated_mlp=True,
    norm="rms",
    rms_plus_one=True,
    post_norms=True,
    embed_scale=True,
    rope_theta=1e4,
    tie_embeddings=True,
)

"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 -- llama+mistral mix, SWA [arXiv:2401.16818].

Llama-style gated-SiLU MLP + RMSNorm with mistral-style sliding-window
attention on every layer (window 4096) => sub-quadratic, runs long_500k."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    head_dim=120,
    pattern=(LayerSpec(kind="attn", attn="swa", mlp="dense"),),
    window=4096,
    mlp_act="silu",
    gated_mlp=True,
    norm="rms",
    rope_theta=1e4,
    tie_embeddings=True,
    long_context=True,
)

"""llava-next-mistral-7b [vlm]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 -- anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Mistral-7B backbone (gated SiLU, RMSNorm, RoPE 1e6, full attention in the
v0.2 lineage).  The anyres vision frontend (CLIP ViT + tiling + projector)
is a STUB per the assignment: input_specs() supplies precomputed patch
embeddings (base grid 576 = 24x24 tokens) which forward_lm splices at
frontend_offset.  long_500k skipped (full attention)."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    pattern=(LayerSpec(kind="attn", attn="full", mlp="dense"),),
    mlp_act="silu",
    gated_mlp=True,
    norm="rms",
    rope_theta=1e6,
    tie_embeddings=False,
    frontend="vision",
    num_frontend_tokens=576,
    frontend_offset=1,
)
